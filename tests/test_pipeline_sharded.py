"""Sharded multi-device quantize_tree (ISSUE 2 tentpole).

The contract: row-partitioning each bucket over the mesh's 'data' axis under
shard_map must be *bit-exact* against both the unsharded batched path and
the serial per-layer oracle — SQuant's flip objective is row-independent, so
the partition is exact, not approximate. Real multi-device coverage comes
from the ``multidevice_run`` conftest harness, which spawns subprocesses
that genuinely see 2 or 8 host-platform devices (CI's CPU-only runners
included). The in-process tests at the bottom additionally run on however
many devices the parent holds (1 on the fast lane; 8 on CI's ``multidevice``
lane, which sets ``--xla_force_host_platform_device_count=8``).
"""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pipeline import quantize_tree
from repro.launch.mesh import make_quantize_mesh

# Tree covers: three dense layers sharing one bucket whose stacked row count
# (3 × 9 = 27) does NOT divide 2 or 8 (exercises the padding), an expert
# bank, and a never-quantized vector.
_TREE_SCRIPT = """
import warnings; warnings.filterwarnings("ignore")
import numpy as np, jax, jax.numpy as jnp
from repro.core.pipeline import quantize_tree
from repro.launch.mesh import make_quantize_mesh

assert len(jax.devices()) == {devices}, jax.devices()
rng = np.random.default_rng(0)
def w(*shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))
tree = {{"blk0": {{"attn": {{"w": w(16, 9)}},
                  "norm": {{"gain": jnp.ones((16,), jnp.float32)}}}},
         "blk1": {{"attn": {{"w": w(16, 9)}}}},
         "blk2": {{"attn": {{"w": w(16, 9)}}}},
         "moe": {{"w": w(3, 16, 24)}}}}

mesh = make_quantize_mesh()
q_sh, rep = quantize_tree(tree, method="squant", bits=4, group_size=8,
                          mesh=mesh)
q_un, _ = quantize_tree(tree, method="squant", bits=4, group_size=8)
q_se, _ = quantize_tree(tree, method="squant", bits=4, group_size=8,
                        batched=False)
for path in (("blk0", "attn"), ("blk1", "attn"), ("blk2", "attn"),
             ("moe",)):
    a, b, c = q_sh, q_un, q_se
    for k in path:
        a, b, c = a[k], b[k], c[k]
    a, b, c = a["w"], b["w"], c["w"]
    assert np.array_equal(np.asarray(a.codes()), np.asarray(b.codes())), path
    assert np.array_equal(np.asarray(a.scale), np.asarray(b.scale)), path
    assert np.array_equal(np.asarray(a.codes()), np.asarray(c.codes())), path
    assert np.array_equal(np.asarray(a.scale), np.asarray(c.scale)), path

# shard breakdown: every device accounted for, rows sum to the real total
assert rep.mesh_axis == "data" and rep.mesh_size == {devices}
assert len(rep.shards) == {devices}
total_rows = 9 * 3 + 3 * 24          # dense bucket rows + expert bank rows
assert sum(s.rows for s in rep.shards) == total_rows, rep.shards
if {devices} > 1:
    assert sum(s.pad_rows for s in rep.shards) > 0   # 27 % ndev != 0
# codes/scales inherited mesh shardings (not single-device)
sh = q_sh["blk0"]["attn"]["w"].data.sharding
assert getattr(sh, "mesh", None) is not None and sh.mesh.size == {devices}, sh
print("SHARDED-OK", rep.summary())
"""


@pytest.mark.parametrize("devices", [2, 8])
def test_sharded_bit_exact_multidevice(multidevice_run, devices):
    """Sharded vs unsharded vs serial codes+scales, 2- and 8-device meshes,
    non-divisible row counts exercising the padding."""
    out = multidevice_run(_TREE_SCRIPT.format(devices=devices),
                          devices=devices, timeout=900)
    assert "SHARDED-OK" in out


def test_sharded_rtn_and_backends_multidevice(multidevice_run):
    """RTN (no flip kernel) and the interpret backend (Pallas kernel body)
    both survive the shard_map row partition bit-exactly."""
    out = multidevice_run(textwrap.dedent("""
        import warnings; warnings.filterwarnings("ignore")
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.pipeline import quantize_tree
        from repro.launch.mesh import make_quantize_mesh
        rng = np.random.default_rng(1)
        tree = {"a": {"w": jnp.asarray(
            rng.normal(size=(16, 12)).astype(np.float32))}}
        mesh = make_quantize_mesh(4)
        for method, backend in (("rtn", "ref"), ("squant", "interpret"),
                                ("squant_e", "ref")):
            q_sh, _ = quantize_tree(tree, method=method, bits=4, group_size=8,
                                    mesh=mesh, backend=backend)
            q_un, _ = quantize_tree(tree, method=method, bits=4, group_size=8,
                                    backend=backend)
            assert np.array_equal(np.asarray(q_sh["a"]["w"].codes()),
                                  np.asarray(q_un["a"]["w"].codes())), method
            assert np.array_equal(np.asarray(q_sh["a"]["w"].scale),
                                  np.asarray(q_un["a"]["w"].scale)), method
        print("BACKENDS-OK")
    """), devices=4, timeout=900)
    assert "BACKENDS-OK" in out


# ---------------------------------------------------------------------------
# In-process coverage: runs on however many devices this process sees
# (1 on the plain fast lane — still a real mesh through the real shard_map
# code path; 8 on the CI multidevice lane).
# ---------------------------------------------------------------------------

def _tree(rng):
    def w(*shape):
        return jnp.asarray(rng.normal(size=shape).astype(np.float32))
    return {"a": {"w": w(16, 8)}, "b": {"w": w(16, 8)},
            "moe": {"w": w(2, 16, 8)}}


def test_sharded_inprocess_bit_exact(rng):
    mesh = make_quantize_mesh()
    src = _tree(rng)
    q_sh, rep = quantize_tree(src, bits=4, group_size=8, mesh=mesh)
    q_un, _ = quantize_tree(src, bits=4, group_size=8)
    for k in ("a", "b", "moe"):
        np.testing.assert_array_equal(np.asarray(q_sh[k]["w"].codes()),
                                      np.asarray(q_un[k]["w"].codes()))
        np.testing.assert_array_equal(np.asarray(q_sh[k]["w"].scale),
                                      np.asarray(q_un[k]["w"].scale))
    ndev = len(jax.devices())
    assert rep.mesh_size == ndev and len(rep.shards) == ndev
    assert sum(s.rows for s in rep.shards) == 8 * 2 + 2 * 8
    if ndev > 1:
        assert rep.mesh_axis == "data"
        assert "sharded data=" in rep.summary()


def test_sharded_dequantize_matches_unsharded(rng):
    mesh = make_quantize_mesh()
    src = _tree(rng)
    t_sh, _ = quantize_tree(src, bits=4, group_size=8, mesh=mesh,
                            dequantize=True)
    t_un, _ = quantize_tree(src, bits=4, group_size=8,
                            dequantize=True)
    for a, b in zip(jax.tree_util.tree_leaves(t_sh),
                    jax.tree_util.tree_leaves(t_un)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharded_single_sync(rng, monkeypatch):
    """The sharded path keeps the batched pipeline's ONE-sync contract."""
    from repro.core import pipeline
    calls = []
    real = pipeline._sync
    monkeypatch.setattr(pipeline, "_sync",
                        lambda x: (calls.append(1), real(x))[1])
    quantize_tree(_tree(rng), bits=4, group_size=8,
                  mesh=make_quantize_mesh())
    assert len(calls) == 1


def test_mesh_validation(rng):
    with pytest.raises(ValueError):        # serial is single-device
        quantize_tree(_tree(rng), mesh=make_quantize_mesh(), batched=False)
    from repro.distributed import compat
    no_data = compat.make_mesh((1,), ("model",))
    with pytest.raises(ValueError):        # mesh must carry the row axis
        quantize_tree(_tree(rng), mesh=no_data)
