"""Equivalence tests for the sequence mixers: chunked vs step-scan RWKV,
associative-scan vs step-decode Mamba, and MoE routing properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba import init_mamba, init_mamba_state, mamba
from repro.models.moe import init_moe, moe_ffn
from repro.models.rwkv import wkv_chunked, wkv_scan


@pytest.mark.parametrize("chunk", [16, 32])
def test_wkv_chunked_equals_scan(rng, chunk):
    b, h, s, d = 2, 3, 64, 16
    r, k, v = (jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
               * 0.5 for _ in range(3))
    logw = jnp.asarray(
        -np.exp(rng.normal(size=(b, h, s, d)).astype(np.float32) * 0.3 - 1.0)
    ).clip(-2.0, -1e-4)
    u = jnp.asarray(rng.normal(size=(h, d)).astype(np.float32) * 0.1)
    s0 = jnp.asarray(rng.normal(size=(b, h, d, d)).astype(np.float32) * 0.1)
    o1, sf1 = wkv_scan(r, k, v, logw, u, s0)
    o2, sf2 = wkv_chunked(r, k, v, logw, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(sf1), np.asarray(sf2), rtol=1e-4,
                               atol=1e-4)


def test_wkv_state_carries_across_calls(rng):
    """Processing [a; b] equals processing a then b with the carried state."""
    b, h, s, d = 1, 2, 32, 8
    def mk():
        return jnp.asarray(
            rng.normal(size=(b, h, s, d)).astype(np.float32)) * 0.5
    r, k, v = mk(), mk(), mk()
    logw = jnp.clip(mk() - 1.0, -2.0, -1e-4)
    u = jnp.asarray(rng.normal(size=(h, d)).astype(np.float32) * 0.1)
    s0 = jnp.zeros((b, h, d, d), jnp.float32)
    o_full, sf_full = wkv_scan(r, k, v, logw, u, s0)
    half = s // 2
    o1, s1 = wkv_scan(r[:, :, :half], k[:, :, :half], v[:, :, :half],
                      logw[:, :, :half], u, s0)
    o2, s2 = wkv_scan(r[:, :, half:], k[:, :, half:], v[:, :, half:],
                      logw[:, :, half:], u, s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 2)),
                               np.asarray(o_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(sf_full),
                               rtol=1e-5, atol=1e-5)


def test_mamba_decode_matches_prefill(rng):
    d, n = 32, 8
    p = init_mamba(jax.random.PRNGKey(0), d, d_state=n)
    x = jnp.asarray(rng.normal(size=(2, 16, d)).astype(np.float32))
    y, _ = mamba(p, x, d_state=n, mode="prefill")
    st = init_mamba_state(2, d, d_state=n)
    outs = []
    for t in range(16):
        yt, st = mamba(p, x[:, t:t + 1], d_state=n, state=st, mode="decode")
        outs.append(yt)
    yd = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yd), rtol=1e-3,
                               atol=1e-3)


def test_mamba_prefill_state_continues(rng):
    d, n = 16, 4
    p = init_mamba(jax.random.PRNGKey(1), d, d_state=n)
    x = jnp.asarray(rng.normal(size=(1, 24, d)).astype(np.float32))
    y_full, _ = mamba(p, x, d_state=n, mode="prefill")
    _, st = mamba(p, x[:, :16], d_state=n, mode="prefill")
    y2, _ = mamba(p, x[:, 16:17], d_state=n, state=st, mode="decode")
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, 16:17]),
                               rtol=1e-3, atol=1e-3)


def test_moe_output_is_gated_expert_mix(rng):
    """With top_k == n_experts and dropless capacity, MoE equals the
    softmax-weighted sum of all expert FFNs."""
    d, ff, e = 16, 32, 4
    p = init_moe(jax.random.PRNGKey(0), d, ff, e, kind="relu")
    x = jnp.asarray(rng.normal(size=(2, 8, d)).astype(np.float32))
    y, _ = moe_ffn(p, x, n_experts=e, top_k=e, kind="relu", dropless=True)
    xt = x.reshape(-1, d)
    probs = jax.nn.softmax(xt @ p["router"]["w"], axis=-1)
    ref = jnp.zeros_like(xt)
    for ei in range(e):
        h = jax.nn.relu(xt @ p["wi"]["w"][ei])
        ref += probs[:, ei:ei + 1] * (h @ p["wdown"]["w"][ei])
    np.testing.assert_allclose(np.asarray(y.reshape(-1, d)), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_moe_capacity_drops_tokens(rng):
    """Tiny capacity → some tokens bypass experts (output 0 for them)."""
    d, ff, e = 8, 16, 4
    p = init_moe(jax.random.PRNGKey(0), d, ff, e, kind="relu")
    x = jnp.asarray(rng.normal(size=(1, 64, d)).astype(np.float32))
    y_full, _ = moe_ffn(p, x, n_experts=e, top_k=2, kind="relu",
                        dropless=True)
    y_tight, _ = moe_ffn(p, x, n_experts=e, top_k=2, kind="relu",
                         capacity_factor=0.25)
    # tight capacity must zero some token outputs that dropless serves
    changed = np.abs(np.asarray(y_full - y_tight)).max(-1) > 1e-6
    assert changed.any()
    aux = moe_ffn(p, x, n_experts=e, top_k=2, kind="relu")[1]
    assert float(aux) > 0
