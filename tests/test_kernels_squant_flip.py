"""Pallas squant_flip kernel vs pure-jnp oracle: shape/dtype/bits sweeps in
interpret mode (kernel body executes on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.quant.scales import compute_scale


def _case(rng, m, n, dtype=np.float32, scale_mult=1.0):
    w = (rng.normal(size=(m, n)) * scale_mult).astype(dtype)
    return jnp.asarray(w)


@pytest.mark.parametrize("m,n,g", [
    (8, 128, 32),      # exact tiles
    (16, 256, 64),
    (5, 96, 32),       # M padding
    (8, 100, 32),      # N padding
    (3, 50, 16),       # both padded
    (1, 16, 16),       # single row, single group
    (8, 512, 128),     # full-width groups
])
@pytest.mark.parametrize("bits", [4, 8])
def test_pallas_matches_ref_shapes(rng, m, n, g, bits):
    w = _case(rng, m, n)
    scale = compute_scale(w, bits, "max")
    got = ops.squant_flip(w, scale, bits=bits, group_size=g,
                          use_pallas="interpret", tm=4)
    want = ref.squant_ref(w, scale, bits=bits, group_size=g)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pallas_c_stage_tile_not_dividing_m(rng):
    """Many groups shrink the C-stage tile via the VMEM cap; a tm_c that does
    not divide the padded M used to leave the last rows' gflip unwritten
    (regression: grid was floor-divided)."""
    m, g, ng = 8, 4, 300          # cap: 2^19 // 300^2 = 5 → must shrink to 4
    w = _case(rng, m, ng * g)
    scale = compute_scale(w, 4, "max")
    got = ops.squant_flip(w, scale, bits=4, group_size=g,
                          use_pallas="interpret", tm=8)
    want = ref.squant_ref(w, scale, bits=4, group_size=g)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pallas_bf16_input_invariants(rng):
    """bf16 inputs produce coarse δ grids with exact .5 ties where summation
    order legitimately differs between implementations — so for bf16 we
    assert the paper's invariants on the kernel output (bit-exactness vs the
    oracle is enforced on the f32 sweeps above)."""
    w = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32)
                    ).astype(jnp.bfloat16)
    scale = compute_scale(w.astype(jnp.float32), 4, "max")
    got = np.asarray(ops.squant_flip(w.astype(jnp.float32), scale, bits=4,
                                     group_size=32, use_pallas="interpret"),
                     np.float64)
    d = got - np.asarray(w, np.float64) / np.asarray(scale)
    assert got.max() <= 7 and got.min() >= -7
    assert np.abs(d).max() < 1.0 + 1e-2
    assert np.abs(d.sum(1)).max() <= 0.5 + 1e-2
    assert np.abs(d.reshape(8, -1, 32).sum(-1)).max() <= 1.0 + 1e-2


@pytest.mark.parametrize("ek,ec", [(False, False), (True, False), (True, True)])
def test_pallas_stage_configs(rng, ek, ec):
    w = _case(rng, 12, 160)
    scale = compute_scale(w, 4, "max")
    got = ops.squant_flip(w, scale, bits=4, group_size=32, enable_k=ek,
                          enable_c=ec, use_pallas="interpret")
    want = ref.squant_ref(w, scale, bits=4, group_size=32, enable_k=ek,
                          enable_c=ec)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pallas_invariants_direct(rng):
    """Invariants hold for the kernel output itself (not just ref-equality)."""
    w = _case(rng, 16, 256)
    scale = compute_scale(w, 4, "max")
    codes = np.asarray(ops.squant_flip(w, scale, bits=4, group_size=64,
                                       use_pallas="interpret"), np.float64)
    d = codes - np.asarray(w) / np.asarray(scale)
    assert np.abs(d.sum(1)).max() <= 0.5 + 1e-4
    assert np.abs(d.reshape(16, -1, 64).sum(-1)).max() <= 1.0 + 1e-4
    assert np.abs(d).max() < 1.0 + 1e-4


def test_pallas_clipping_scale(rng):
    w = _case(rng, 8, 128, scale_mult=4.0)
    scale = jnp.full((8, 1), 0.5, jnp.float32)   # heavy clipping
    got = ops.squant_flip(w, scale, bits=4, group_size=32,
                          use_pallas="interpret")
    want = ref.squant_ref(w, scale, bits=4, group_size=32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.asarray(got).max() <= 7 and np.asarray(got).min() >= -7
