"""Tests for the sharded quantized serving format (w_q/w_q4 + w_scale):
structure, numerical agreement with the dense model, and scan compatibility.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.quant.apply import (dequant_kernel, quantize_params_sharded,
                               quantized_param_shapes)


def _model(arch="granite-3-8b"):
    cfg = get_config(arch, reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0)), cfg


@pytest.mark.parametrize("bits", [8, 4])
def test_shapes_match_real_quant(bits):
    model, params, _ = _model()
    shapes = quantized_param_shapes(model.param_shapes(), bits)
    real = quantize_params_sharded(params, bits)
    for (kp1, s), (kp2, r) in zip(
            jax.tree_util.tree_flatten_with_path(shapes)[0],
            jax.tree_util.tree_flatten_with_path(real)[0]):
        assert jax.tree_util.keystr(kp1) == jax.tree_util.keystr(kp2)
        assert tuple(s.shape) == tuple(r.shape), jax.tree_util.keystr(kp1)
        assert s.dtype == r.dtype


def test_dequant_roundtrip_w8():
    model, params, _ = _model()
    q = quantize_params_sharded(params, 8)
    # find one stacked kernel and compare dequant vs dense
    stack = q["stack"]["periods"]["b0"]["attn"]["wq"]
    w_dense = params["stack"]["periods"]["b0"]["attn"]["wq"]["w"]
    w_deq = dequant_kernel(stack, jnp.float32)       # (P, out, in)
    want = jnp.moveaxis(w_dense, -1, -2)
    err = np.abs(np.asarray(w_deq) - np.asarray(want))
    assert err.max() < np.abs(np.asarray(want)).max() / 50


@pytest.mark.parametrize("arch", ["granite-3-8b", "mixtral-8x7b",
                                  "rwkv6-1.6b"])
def test_quantized_forward_close(arch):
    """w8 quantized serving tree produces near-dense logits under the
    scanned stack (decode path included)."""
    model, params, cfg = _model(arch)
    q8 = quantize_params_sharded(params, 8)
    batch = {"tokens": jnp.asarray([[5, 6, 7, 9]], jnp.int32)}
    c1 = model.init_cache(1, 8)
    c2 = model.init_cache(1, 8)
    l1, c1 = jax.jit(model.prefill)(params, batch, c1)
    l2, c2 = jax.jit(model.prefill)(q8, batch, c2)
    scale = float(np.abs(np.asarray(l1)).max())
    assert float(np.abs(np.asarray(l1 - l2)).max()) < 0.08 * scale
    tok = jnp.asarray([[3]], jnp.int32)
    d1, _ = jax.jit(model.decode_step)(params, tok, c1)
    d2, _ = jax.jit(model.decode_step)(q8, tok, c2)
    assert float(np.abs(np.asarray(d1 - d2)).max()) < 0.08 * scale


def test_w4_forward_runs():
    model, params, _ = _model()
    q4 = quantize_params_sharded(params, 4)
    batch = {"tokens": jnp.asarray([[1, 2, 3, 4]], jnp.int32)}
    cache = model.init_cache(1, 8)
    logits, _ = jax.jit(model.prefill)(q4, batch, cache)
    assert np.all(np.isfinite(np.asarray(logits)))
