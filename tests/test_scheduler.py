"""Continuous-batching scheduler tests.

Covers: bit-identical greedy tokens vs the round engine on a mixed-length
batch; late (refill) admission equivalence via the shared-clock padding
semantics; admission queueing when all slots are busy; EOS retirement
freeing a slot mid-stream for a queued request; reload drain semantics
(drain-fully vs swap-deadline force-drain) with per-slot version pinning;
clock-horizon wave resets; and the round scheduler's sized-to-actual-batch
fix (no retrace across same-shape rounds, batch-size-independent tokens).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.serving.engine import Request, ServeConfig, ServeEngine


def _tiny(seed=0, vocab=256, **over):
    cfg = get_config("granite-3-8b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32", n_layers=2, d_model=32,
                              n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
                              vocab=vocab, **over)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(seed))


def _mixed_reqs():
    return [Request(prompt=[1, 2, 3, 4, 5, 6], max_new_tokens=8,
                    request_id=0),
            Request(prompt=[7, 8], max_new_tokens=3, request_id=1),
            Request(prompt=[9, 10, 11], max_new_tokens=5, request_id=2),
            Request(prompt=[4, 4, 4, 4], max_new_tokens=6, request_id=3)]


def _engines(model, params, **over):
    base = dict(max_batch=4, max_len=32)
    base.update(over)
    rnd = ServeEngine(model, params, ServeConfig(**base))
    cont = ServeEngine(model, params,
                       ServeConfig(scheduler="continuous", **base))
    return rnd, cont


# ---------------------------------------------------------------------------
# token-level equivalence with the round engine (greedy)
# ---------------------------------------------------------------------------

def test_mixed_length_batch_bit_identical_to_round():
    """A mixed-length batch admitted in one wave uses exactly the round
    engine's left-padding, and every serving op is row-independent — greedy
    tokens must match bit-for-bit, per request."""
    model, params = _tiny()
    rnd, cont = _engines(model, params)
    ro = rnd.generate(_mixed_reqs())
    co = cont.generate(_mixed_reqs())
    assert [o.tokens for o in ro] == [o.tokens for o in co]
    # short requests retired early: the pool emptied in max(max_new) steps
    sch = cont.stats()["scheduler"]
    assert sch["steps"] == 8 and sch["waves"] == 1
    assert sch["retired"] == 4


def test_refill_admission_equivalent_to_round_padding():
    """A request admitted into a freed slot at clock P is left-padded to P
    — the same tokens the round engine produces for that request padded to
    a round plen of P (forced here with a length-P filler prompt)."""
    model, params = _tiny()
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=2, request_id=0),
            Request(prompt=[5, 6, 7, 8, 9], max_new_tokens=12,
                    request_id=1),
            Request(prompt=[11, 12], max_new_tokens=4, request_id=2)]
    cont = ServeEngine(model, params,
                       ServeConfig(max_batch=2, max_len=32,
                                   scheduler="continuous"))
    co = cont.generate(reqs)
    adm = {e["request_id"]: e for e in cont.scheduler.admission_log}
    # request 2 was admitted mid-flight into request 0's freed slot, after
    # the wave (clock 5) had advanced past 0's retirement
    assert adm[2]["clock"] > adm[0]["clock"] == adm[1]["clock"] == 5
    pad = adm[2]["clock"]
    # round-engine control: co-batch with a filler whose prompt length pins
    # the round's plen to `pad` (rows are independent, so the filler cannot
    # affect request 2's tokens — only its padding)
    rnd = ServeEngine(model, params, ServeConfig(max_batch=2, max_len=32))
    ctrl = rnd.generate(
        [Request(prompt=reqs[2].prompt, max_new_tokens=4, request_id=2),
         Request(prompt=[3] * pad, max_new_tokens=1, request_id=99)])
    assert co[2].tokens == ctrl[0].tokens
    # and the long request was never disturbed by the mid-flight admission
    solo = rnd.generate([reqs[0], reqs[1]])
    assert co[1].tokens == solo[1].tokens


def test_round_tokens_independent_of_batch_size_and_no_retrace():
    """The round scheduler sizes prefill/cache to the actual batch: a
    2-request round on an 8-slot engine matches a 2-slot engine bit-for-bit
    (row independence), and repeated same-shape rounds never retrace."""
    model, params = _tiny()
    reqs = _mixed_reqs()[:2]
    big = ServeEngine(model, params, ServeConfig(max_batch=8, max_len=32))
    small = ServeEngine(model, params, ServeConfig(max_batch=2, max_len=32))
    a = big.generate(reqs)
    assert [o.tokens for o in a] == \
        [o.tokens for o in small.generate(reqs)]
    assert big.trace_counts == {"prefill": 1, "prefill_chunk": 0,
                                "decode": 1, "admit": 0}
    for _ in range(3):                      # same shapes: no retrace
        assert [o.tokens for o in big.generate(reqs)] == \
            [o.tokens for o in a]
    assert big.trace_counts == {"prefill": 1, "prefill_chunk": 0,
                                "decode": 1, "admit": 0}
    big.generate(_mixed_reqs()[:3])         # new batch size: one new trace
    assert big.trace_counts["prefill"] == 2
    assert big.trace_counts["decode"] == 2


def test_continuous_decode_traces_once_across_refills():
    """The continuous decode loop always runs the (max_slots, 1) shape —
    admissions and retirements never retrace it."""
    model, params = _tiny()
    cont = ServeEngine(model, params,
                       ServeConfig(max_batch=2, max_len=48,
                                   scheduler="continuous"))
    reqs = [Request(prompt=[1 + i, 2, 3], max_new_tokens=3 + (i % 3) * 2,
                    request_id=i) for i in range(6)]
    outs = cont.generate(reqs)
    assert [len(o.tokens) for o in outs] == [3, 5, 7, 3, 5, 7]
    assert cont.trace_counts["decode"] == 1
    sch = cont.stats()["scheduler"]
    assert sch["admitted"] == 6 and sch["max_occupancy"] == 2


# ---------------------------------------------------------------------------
# slot lifecycle
# ---------------------------------------------------------------------------

def test_admission_queues_when_all_slots_busy():
    model, params = _tiny()
    cont = ServeEngine(model, params,
                       ServeConfig(max_batch=2, max_len=64,
                                   scheduler="continuous"))
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=3 + 2 * (i % 2),
                    request_id=i) for i in range(5)]
    outs = cont.generate(reqs)
    assert all(len(o.tokens) == r.max_new_tokens
               for o, r in zip(outs, reqs))
    sch = cont.stats()["scheduler"]
    assert sch["admitted"] == 5 and sch["max_occupancy"] <= 2
    # staggered retirement → staggered refills: at most the wave's two
    # admissions share a clock
    clocks = [e["clock"] for e in cont.scheduler.admission_log]
    assert max(np.bincount(clocks)) <= 2


def test_eos_retirement_frees_slot_for_queued_request():
    """A slot that hits EOS mid-stream retires immediately; a queued
    request takes the slot while the co-admitted long request is still
    decoding."""
    model, params = _tiny()
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=10, request_id=0),
            Request(prompt=[5, 6, 7], max_new_tokens=10, request_id=1),
            Request(prompt=[11, 12], max_new_tokens=4, request_id=2)]

    def run(eos):
        eng = ServeEngine(model, params,
                          ServeConfig(max_batch=2, max_len=32, eos_id=eos,
                                      scheduler="continuous"))
        return eng, eng.generate(reqs)

    _, base = run(-1)
    # pick an EOS value from request 0's early stream that request 1 never
    # emits, so only request 0 stops early
    eos = next(t for t in base[0].tokens[:6]
               if t not in base[1].tokens and t != 0)
    cut = base[0].tokens.index(eos) + 1
    eng, outs = run(eos)
    assert outs[0].tokens == base[0].tokens[:cut]       # truncated at EOS
    assert len(outs[1].tokens) == 10                    # undisturbed
    adm = {e["request_id"]: e for e in eng.scheduler.admission_log}
    # request 2 entered request 0's freed slot while request 1 still ran
    assert adm[2]["slot"] == adm[0]["slot"]
    assert adm[2]["clock"] < adm[1]["clock"] + 10


def test_wave_reset_reuses_pool_within_max_len_horizon():
    """Admission respects the cache horizon (clock + max_new <= max_len);
    when the pool empties the clock rewinds and the same pool cache serves
    a fresh wave — tokens identical to the round engine's rounds."""
    model, params = _tiny()
    reqs = [Request(prompt=[1 + i, 2, 3], max_new_tokens=10, request_id=i)
            for i in range(4)]
    rnd = ServeEngine(model, params, ServeConfig(max_batch=2, max_len=16))
    cont = ServeEngine(model, params,
                       ServeConfig(max_batch=2, max_len=16,
                                   scheduler="continuous"))
    ro, co = rnd.generate(reqs), cont.generate(reqs)
    assert [o.tokens for o in ro] == [o.tokens for o in co]
    sch = cont.stats()["scheduler"]
    assert sch["waves"] == 2                       # horizon forced a reset
    clocks = [e["clock"] for e in cont.scheduler.admission_log]
    assert clocks == [3, 3, 3, 3]                  # both waves left-pad to 3


@pytest.mark.parametrize("scheduler", ["round", "continuous"])
def test_oversized_request_rejected(scheduler):
    """Both schedulers reject a request whose prompt+budget exceeds the
    cache horizon instead of letting dynamic_update_slice clamp onto the
    last cache row and silently corrupt decode."""
    model, params = _tiny()
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_len=16,
                                  scheduler=scheduler))
    with pytest.raises(ValueError, match="exceeds"):
        eng.generate([Request(prompt=[1] * 10, max_new_tokens=10)])


def test_zero_budget_request_completes_empty():
    model, params = _tiny()
    cont = ServeEngine(model, params,
                       ServeConfig(max_batch=2, max_len=32,
                                   scheduler="continuous"))
    outs = cont.generate([Request(prompt=[1, 2], max_new_tokens=0,
                                  request_id=7),
                          Request(prompt=[1, 2], max_new_tokens=3,
                                  request_id=8)])
    assert outs[0].tokens == [] and len(outs[1].tokens) == 3


def test_encdec_not_supported_by_continuous():
    cfg = get_config("granite-3-8b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32", n_layers=2, d_model=32,
                              n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
                              vocab=64, encoder_layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="encoder-decoder"):
        ServeEngine(model, params,
                    ServeConfig(max_batch=2, max_len=32,
                                scheduler="continuous"))


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "rwkv6-1.6b"])
def test_continuous_other_archs_smoke(arch):
    cfg = get_config(arch, reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_len=48,
                                  scheduler="continuous"))
    outs = eng.generate([Request(prompt=[3, 1, 4], max_new_tokens=4,
                                 request_id=i) for i in range(3)])
    assert all(len(o.tokens) == 4 for o in outs)


# ---------------------------------------------------------------------------
# chunked prefill: bit-identical admission, interleaving, starvation guard
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [1, 2, 5, 6, 7])
def test_chunked_fresh_wave_bit_identical_to_monolithic(chunk):
    """A fresh wave prefilled in chunks (sizes 1, non-dividing, exactly the
    wave padding, and larger than it) produces bit-identical greedy tokens
    to the monolithic admission path: the chunk continuation runs the same
    prefill einsums against the cache prefix, and masked-out columns
    contribute exact zeros."""
    model, params = _tiny()
    mono = ServeEngine(model, params,
                       ServeConfig(max_batch=4, max_len=32,
                                   scheduler="continuous"))
    chunked = ServeEngine(model, params,
                          ServeConfig(max_batch=4, max_len=32,
                                      scheduler="continuous",
                                      prefill_chunk=chunk))
    chunked.scheduler.step_log = steps = []
    mo = mono.generate(_mixed_reqs())
    co = chunked.generate(_mixed_reqs())
    assert [o.tokens for o in mo] == [o.tokens for o in co]
    ms, cs = mono.stats()["scheduler"], chunked.stats()["scheduler"]
    assert cs["steps"] == ms["steps"] == 8     # sampling steps unchanged
    # wave padding 6 consumed `chunk` positions per prefill forward
    assert cs["chunk_steps"] == -(-6 // chunk)
    assert cs["pendings_started"] == 1 and cs["pendings_abandoned"] == 0
    assert chunked.trace_counts["prefill"] == 0
    assert chunked.trace_counts["decode"] == 1
    # per-step tail-latency observability rides along with chunking
    assert steps and all("step_ms" in e and "chunk_ms" in e for e in steps)
    assert set(cs["step_ms"]) == {"p50", "p95", "p99"}


def test_chunked_midflight_admission_equivalent_at_equal_padding():
    """A chunked admission into a freed slot commits to completion clock P
    and left-pads to P — bit-identical to the round engine at padding P
    (pinned with a filler prompt) while the resident keeps decoding."""
    model, params = _tiny()
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=2, request_id=0),
            Request(prompt=[5, 6, 7, 8, 9], max_new_tokens=12,
                    request_id=1),
            Request(prompt=[11, 12], max_new_tokens=4, request_id=2)]
    cont = ServeEngine(model, params,
                       ServeConfig(max_batch=2, max_len=64,
                                   scheduler="continuous",
                                   prefill_chunk=2))
    co = cont.generate(reqs)
    adm = {e["request_id"]: e for e in cont.scheduler.admission_log}
    # request 0 retires at clock 7; the pending (chunk=2 nets one position
    # of catch-up per step against the moving clock) commits to P=12
    assert adm[2]["clock"] == 12 and adm[2]["chunks"] == 6
    rnd = ServeEngine(model, params, ServeConfig(max_batch=2, max_len=64))
    ctrl = rnd.generate(
        [Request(prompt=reqs[2].prompt, max_new_tokens=4, request_id=2),
         Request(prompt=[3] * adm[2]["clock"], max_new_tokens=1,
                 request_id=99)])
    assert co[2].tokens == ctrl[0].tokens
    # the resident long request never noticed the interleaved prefill
    solo = rnd.generate([reqs[0], reqs[1]])
    assert co[1].tokens == solo[1].tokens


def test_chunked_admits_prompt_longer_than_clock():
    """Chunked prefill admits a prompt longer than the current clock (the
    chunks catch up to a committed future clock) — an admission the
    monolithic path cannot express at all; tokens still match the round
    engine at the committed padding."""
    model, params = _tiny()
    long_prompt = [7, 3, 9, 4, 2, 8, 6, 1, 5, 2, 4, 6]       # L=12
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=2, request_id=0),
            Request(prompt=[5, 6, 7], max_new_tokens=24, request_id=1),
            Request(prompt=long_prompt, max_new_tokens=4, request_id=2)]
    cont = ServeEngine(model, params,
                       ServeConfig(max_batch=2, max_len=64,
                                   scheduler="continuous",
                                   prefill_chunk=4))
    co = cont.generate(reqs)
    adm = {e["request_id"]: e for e in cont.scheduler.admission_log}
    assert adm[2]["clock"] >= len(long_prompt) > adm[0]["clock"]
    rnd = ServeEngine(model, params, ServeConfig(max_batch=2, max_len=64))
    ctrl = rnd.generate(
        [Request(prompt=long_prompt, max_new_tokens=4, request_id=2),
         Request(prompt=[3] * adm[2]["clock"], max_new_tokens=1,
                 request_id=99)])
    assert co[2].tokens == ctrl[0].tokens


def test_chunk_one_midflight_waits_for_empty_pool():
    """chunk=1 can never catch a moving clock, so a mid-flight admission
    waits for the pool to empty (frozen clock) and lands as a fresh wave at
    its own prompt length."""
    model, params = _tiny()
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=4, request_id=0),
            Request(prompt=[5, 6, 7, 8], max_new_tokens=6, request_id=1),
            Request(prompt=[11, 12], max_new_tokens=3, request_id=2)]
    cont = ServeEngine(model, params,
                       ServeConfig(max_batch=2, max_len=32,
                                   scheduler="continuous",
                                   prefill_chunk=1))
    co = cont.generate(reqs)
    adm = {e["request_id"]: e for e in cont.scheduler.admission_log}
    assert adm[2]["clock"] == 2                # fresh wave at its own L
    assert cont.stats()["scheduler"]["waves"] == 2
    rnd = ServeEngine(model, params, ServeConfig(max_batch=2, max_len=32))
    solo = rnd.generate([reqs[2]])
    assert co[2].tokens == solo[0].tokens


def test_chunked_interleaves_with_eos_retirement():
    """Residents retiring on EOS mid-pending (emptying the pool and
    freezing the clock) never disturb the chunked admission: it completes
    back-to-back and its tokens match the round engine at the committed
    padding."""
    model, params = _tiny()
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=2, request_id=0),
            Request(prompt=[5, 6, 7], max_new_tokens=10, request_id=1),
            Request(prompt=[11, 12], max_new_tokens=4, request_id=2)]
    base = ServeEngine(model, params,
                       ServeConfig(max_batch=2, max_len=64,
                                   scheduler="continuous",
                                   prefill_chunk=2)).generate(reqs)
    # an EOS request 1 emits early, and request 2 never does
    eos = next(t for t in base[1].tokens[:5]
               if t not in base[2].tokens and t != 0)
    cont = ServeEngine(model, params,
                       ServeConfig(max_batch=2, max_len=64, eos_id=eos,
                                   scheduler="continuous",
                                   prefill_chunk=2))
    co = cont.generate(reqs)
    cut = base[1].tokens.index(eos) + 1
    assert co[1].tokens == base[1].tokens[:cut]
    adm = {e["request_id"]: e for e in cont.scheduler.admission_log}
    rnd = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_len=64, eos_id=eos))
    ctrl = rnd.generate(
        [Request(prompt=reqs[2].prompt, max_new_tokens=4, request_id=2),
         Request(prompt=[3] * adm[2]["clock"], max_new_tokens=1,
                 request_id=99)])
    assert co[2].tokens == ctrl[0].tokens


def test_chunked_pending_drains_before_swap():
    """A staged reload drains a chunked admission like any in-flight work:
    the pending finishes its prefill and its request completes on the old
    version; the swap lands once the pool is empty and later admissions
    serve the new version."""
    model, params = _tiny(0)
    _, params2 = _tiny(1)
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_len=64,
                                  scheduler="continuous",
                                  prefill_chunk=2,
                                  swap_deadline_ms=None))
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=2, request_id=0),
            Request(prompt=[5, 6, 7, 8, 9], max_new_tokens=12,
                    request_id=1),
            Request(prompt=[11, 12], max_new_tokens=4, request_id=2),
            Request(prompt=[13, 14], max_new_tokens=3, request_id=3)]
    _stage_at_step(eng, 5, params2)            # pending for req 2 in flight
    outs = eng.generate(reqs)
    assert [o.weights_version for o in outs] == [1, 1, 1, 2]
    assert all(o.forced_swaps == 0 for o in outs)
    assert all(len(o.tokens) == r.max_new_tokens
               for o, r in zip(outs, reqs))
    st = eng.stats()
    assert st["scheduler"]["pendings_abandoned"] == 0
    assert st["scheduler"]["forced_swaps"] == 0
    assert st["weights"]["swaps"] == 1


def test_force_swap_abandons_pending_and_requeues():
    """A deadline force-swap mid-pending abandons the chunked admission
    (its chunks ran on the outgoing weights): the requests re-queue at the
    front, re-admit under the new version, and their tokens match a round
    engine on the new weights at the re-admission padding."""
    model, params = _tiny(0)
    _, params2 = _tiny(1)
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_len=64,
                                  scheduler="continuous",
                                  prefill_chunk=2,
                                  swap_deadline_ms=0.0))
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=2, request_id=0),
            Request(prompt=[5, 6, 7, 8, 9], max_new_tokens=16,
                    request_id=1),
            Request(prompt=[11, 12], max_new_tokens=4, request_id=2)]
    _stage_at_step(eng, 5, params2)            # pending for req 2 in flight
    outs = eng.generate(reqs)
    st = eng.stats()
    assert st["scheduler"]["pendings_abandoned"] == 1
    assert st["scheduler"]["forced_swaps"] == 1
    assert outs[1].forced_swaps == 1           # in flight across the swap
    assert outs[2].weights_version == 2        # re-admitted post-swap
    assert all(len(o.tokens) == r.max_new_tokens
               for o, r in zip(outs, reqs))
    adm = [e for e in eng.scheduler.admission_log
           if e["request_id"] == 2]
    assert adm[-1]["version"] == 2
    # the abandoned side cache left no trace: tokens match a fresh round
    # engine on the NEW weights at the re-admission padding
    rnd = ServeEngine(model, params2,
                      ServeConfig(max_batch=2, max_len=64))
    ctrl = rnd.generate(
        [Request(prompt=reqs[2].prompt, max_new_tokens=4, request_id=2),
         Request(prompt=[3] * adm[-1]["clock"], max_new_tokens=1,
                 request_id=99)])
    assert outs[2].tokens == ctrl[0].tokens


@pytest.mark.parametrize("scheduler_chunk", [4, 0])
def test_starvation_guard_bounds_head_skips(scheduler_chunk):
    """FCFS-with-skip regression: a stream of short requests behind a long
    one used to refill freed slots forever, so the pool never emptied and
    the long request starved until the whole queue drained. Past
    ``starvation_limit`` head-skips, admission narrows to the head: the
    pool drains into a fresh wave that must admit it."""
    model, params = _tiny()
    long_req = Request(prompt=[9] * 20, max_new_tokens=4, request_id=2)
    # staggered budgets: retirements alternate, so refills keep the pool
    # from ever emptying while any short remains queued
    shorts = [Request(prompt=[1 + i, 2], max_new_tokens=3 + 3 * (i % 2),
                      request_id=10 + i) for i in range(6)]
    reqs = shorts[:2] + [long_req] + shorts[2:]
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_len=64,
                                  scheduler="continuous",
                                  prefill_chunk=scheduler_chunk,
                                  starvation_limit=2))
    outs = eng.generate(reqs)
    assert all(len(o.tokens) == r.max_new_tokens
               for o, r in zip(outs, reqs))
    order = [e["request_id"] for e in eng.scheduler.admission_log]
    # the long request was admitted before the queue ran dry behind it
    assert order.index(2) < len(order) - 2
    # wave reset / head admission cleared the skip bookkeeping
    assert eng.scheduler._head_skips == 0


def test_arch_gates_for_unsupported_stacks():
    """The remaining architecture gates (engine.ARCH_GATES): chunked
    prefill no longer rejects any decoder-only stack — window/MoE stacks
    serve under their composed agreement budget — but the paged backend
    still requires per-position cache rows, so non-positional mixers
    (mamba here) are rejected up front with a pointer to contiguous."""
    cfg = get_config("mixtral-8x7b", reduced=True)   # window + MoE
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # PR-10 gate lift: mixtral chunked prefill constructs and serves
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_len=32,
                                  scheduler="continuous", prefill_chunk=4))
    outs = eng.generate([Request(prompt=[1, 2, 3, 4, 5, 6],
                                 max_new_tokens=3, request_id=0)])
    assert len(outs[0].tokens) == 3
    assert eng.trace_counts["prefill_chunk"] > 0
    eng.close()
    # paged × recurrent state stays gated (per-position rows required)
    jcfg = dataclasses.replace(
        get_config("jamba-1.5-large-398b", reduced=True), dtype="float32",
        n_layers=2, block_pattern=("m", "a"), moe=None)
    jmodel = build_model(jcfg)
    jparams = jmodel.init(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="paged KV cache"):
        ServeEngine(jmodel, jparams,
                    ServeConfig(max_batch=2, max_len=32,
                                scheduler="continuous",
                                kv_backend="paged", block_size=8))
    # quantize_kv × prefill_chunk composes (PR-8 gate lift): the
    # engine constructs and serves rather than raising
    tiny_model, tiny_params = _tiny()
    eng = ServeEngine(tiny_model, tiny_params,
                      ServeConfig(max_batch=2, max_len=32, quantize_kv=True,
                                  scheduler="continuous", prefill_chunk=4))
    outs = eng.generate([Request(prompt=[1, 2, 3, 4, 5, 6],
                                 max_new_tokens=4, request_id=0)])
    assert len(outs[0].tokens) == 4
    assert eng.trace_counts["prefill_chunk"] > 0


# ---------------------------------------------------------------------------
# reload-awareness: drain, deadline force-swap, version pinning
# ---------------------------------------------------------------------------

def _stage_at_step(eng, step, params2):
    def hook(info):
        if info["step"] == step and not eng.store.staged_pending:
            eng.store.stage(fp_params=params2, source="midrun", block=True)
    eng.on_step = hook


def test_drain_fully_before_swap():
    """With no deadline, a staged version waits for every in-flight slot:
    admission pauses, in-flight requests finish on their pinned version,
    and the refill wave serves the new one."""
    model, params = _tiny(0)
    _, params2 = _tiny(1)
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_len=64,
                                  scheduler="continuous",
                                  swap_deadline_ms=None))
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=4, request_id=0),
            Request(prompt=[4, 5, 6], max_new_tokens=12, request_id=1),
            Request(prompt=[7, 8], max_new_tokens=4, request_id=2),
            Request(prompt=[9, 10], max_new_tokens=4, request_id=3)]
    _stage_at_step(eng, 2, params2)
    outs = eng.generate(reqs)
    assert [o.weights_version for o in outs] == [1, 1, 2, 2]
    assert all(o.forced_swaps == 0 for o in outs)
    assert all(len(o.tokens) == r.max_new_tokens
               for o, r in zip(outs, reqs))
    # request 0's slot freed at step 4, but draining paused admission:
    # requests 2/3 entered only after the swap, as a fresh wave
    adm = {e["request_id"]: e for e in eng.scheduler.admission_log}
    assert adm[2]["version"] == adm[3]["version"] == 2
    st = eng.stats()
    assert st["scheduler"]["drains"] == 1
    assert st["scheduler"]["forced_swaps"] == 0
    assert st["weights"]["swaps"] == 1
    assert st["weights"]["forced_swaps"] == 0


def test_swap_deadline_forces_mid_flight_swap():
    """With swap_deadline_ms=0 a staged version lands at the very next
    step boundary: in-flight slots finish on the NEW weights (recorded via
    Completion.forced_swaps) instead of stalling the reload."""
    model, params = _tiny(0)
    _, params2 = _tiny(1)
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_len=64,
                                  scheduler="continuous",
                                  swap_deadline_ms=0.0))
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=10, request_id=0),
            Request(prompt=[4, 5, 6], max_new_tokens=10, request_id=1),
            Request(prompt=[7, 8], max_new_tokens=4, request_id=2)]
    _stage_at_step(eng, 2, params2)
    outs = eng.generate(reqs)
    # in-flight slots keep their admission-pinned version but record the
    # forced swap; the queued request is admitted under the new version
    assert [o.weights_version for o in outs] == [1, 1, 2]
    assert [o.forced_swaps for o in outs] == [1, 1, 0]
    assert all(len(o.tokens) == r.max_new_tokens
               for o, r in zip(outs, reqs))
    st = eng.stats()
    assert st["scheduler"]["forced_swaps"] == 1
    assert st["weights"]["forced_swaps"] == 1
    # the forced swap really changed the decode weights mid-flight: the
    # first tokens match a no-reload run, the tail diverges from it
    ctrl = ServeEngine(model, params,
                       ServeConfig(max_batch=2, max_len=64,
                                   scheduler="continuous"))
    base = ctrl.generate(reqs)
    assert outs[0].tokens[:2] == base[0].tokens[:2]
    assert outs[0].tokens != base[0].tokens


def test_drain_dip_smaller_than_round_blocking():
    """The scheduling win the bench measures, at test scale: after a
    mid-run staging, the continuous engine admits the queued request as
    soon as the swap lands, while the round engine blocks it behind the
    whole first round."""
    model, params = _tiny(0)
    _, params2 = _tiny(1)
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=4, request_id=0),
            Request(prompt=[4, 5, 6], max_new_tokens=20, request_id=1),
            Request(prompt=[7, 8], max_new_tokens=4, request_id=2)]
    cont = ServeEngine(model, params,
                       ServeConfig(max_batch=2, max_len=64,
                                   scheduler="continuous",
                                   swap_deadline_ms=0.0))
    _stage_at_step(cont, 2, params2)
    cont.scheduler.step_log = steps = []
    cont.generate(reqs)
    # after the forced swap, request 2 refilled request 0's slot while the
    # long request still ran: occupancy recovered to 2 on the new version
    post_swap = [e for e in steps if e["version"] == 2]
    assert post_swap and max(e["recorded"] for e in post_swap) >= 2
    # ...so the whole workload finished inside the long request's shadow,
    # where the round engine serializes it (20 + 4 steps)
    assert cont.stats()["scheduler"]["steps"] < 20 + 4
