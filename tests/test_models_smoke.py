"""Per-architecture smoke tests: reduced same-family configs run one forward
/ train step on CPU, asserting output shapes and finiteness; plus
prefill→decode consistency for representative families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs
from repro.models.model import build_model

ARCHS = list_archs()


def _batch(cfg, b=2, s=32, key=0):
    k = jax.random.PRNGKey(key)
    tokens = jax.random.randint(k, (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.is_encdec:
        batch["enc_frames"] = jax.random.normal(
            k, (b, max(1, s // cfg.enc_ratio), cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, reduced=True)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    assert float(metrics["xent"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_grads_finite(arch):
    cfg = get_config(arch, reduced=True)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, b=1, s=16)
    grads = jax.jit(jax.grad(lambda p: model.train_loss(p, batch)[0]))(params)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat), arch
    # at least some gradient is nonzero
    assert any(float(jnp.abs(g).max()) > 0 for g in flat), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = get_config(arch, reduced=True)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s, extra = 2, 16, 4
    batch = _batch(cfg, b=b, s=s)
    cache = model.init_cache(b, s + extra)
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    assert logits.shape == (b, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(extra):
        logits, cache = step(params, tok, cache)
        assert logits.shape == (b, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits)))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert int(cache["pos"]) == s + extra


@pytest.mark.parametrize("arch", ["granite-3-8b", "mixtral-8x7b",
                                  "rwkv6-1.6b", "minicpm3-4b",
                                  "jamba-1.5-large-398b"])
def test_decode_matches_teacher_forcing(arch):
    """Prefill+decode logits equal full-sequence forward logits."""
    import dataclasses
    cfg = get_config(arch, reduced=True)
    moe = cfg.moe
    if moe is not None:
        # dropless capacity so teacher forcing and incremental routing agree
        moe = dataclasses.replace(moe, capacity_factor=1e3)
    cfg = dataclasses.replace(cfg, dtype="float32", remat=False, moe=moe)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, s = 2, 12
    batch = _batch(cfg, b=b, s=s, key=3)
    # full forward (teacher forcing)
    full_logits, _, _ = jax.jit(
        lambda p, bt: model.forward(p, bt, mode="train"))(params, batch)
    # prefill on the first s-4 tokens, then decode the rest
    cut = s - 4
    pre = {k: (v[:, :cut] if v.ndim >= 2 and v.shape[1] == s else v)
           for k, v in batch.items()}
    cache = model.init_cache(b, s)
    logits, cache = jax.jit(model.prefill)(params, pre, cache)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, cut - 1]),
                               rtol=2e-3, atol=2e-3)
    step = jax.jit(model.decode_step)
    for t in range(cut, s):
        logits, cache = step(params, batch["tokens"][:, t:t + 1], cache)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits[:, t]),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"{arch} step {t}")


def test_swa_ring_buffer_long_prefill():
    """Mixtral-style SWA: prefill longer than the window, then decode."""
    cfg = get_config("mixtral-8x7b", reduced=True)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32",
                           "window": 8, "remat": False})
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 1, 24          # 3× window
    batch = _batch(cfg, b=b, s=s, key=5)
    full_logits, _, _ = model.forward(params, batch, mode="train")
    cache = model.init_cache(b, s + 8)
    logits, cache = model.prefill(params, batch, cache)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-3, atol=2e-3)
    # decode continues coherently (window slides over the ring)
    tok = batch["tokens"][:, -1:]
    logits2, cache = model.decode_step(params, tok, cache)
    assert np.all(np.isfinite(np.asarray(logits2)))


def test_all_input_specs_defined():
    for arch in ARCHS:
        cfg = get_config(arch)
        model = build_model(cfg)
        for name, sh in SHAPES.items():
            spec = model.input_specs(sh)
            assert "tokens" in spec
            if sh.kind == "decode":
                assert spec["tokens"].shape == (sh.global_batch, 1)
            else:
                assert spec["tokens"].shape == (sh.global_batch, sh.seq_len)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_bf16_dtype_stable(arch):
    """bf16 models must keep scan carries dtype-stable (prefill + decode)."""
    cfg = get_config(arch, reduced=True)   # default dtype bfloat16
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 8
    batch = _batch(cfg, b=b, s=s)
    if cfg.is_encdec:
        batch["enc_frames"] = batch["enc_frames"].astype(jnp.bfloat16)
    cache = model.init_cache(b, s + 2)
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    step = jax.jit(model.decode_step)
    for _ in range(2):
        logits, cache = step(params, tok, cache)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
