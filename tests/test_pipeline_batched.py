"""Batched pipeline (ISSUE 1 tentpole) vs the per-layer reference path.

The contract: bucketing + stacking + one dispatch per bucket + one sync total
must be *bit-exact* against the legacy serial loop for every method, and the
interpret backend (Pallas kernel body on CPU) must match the jnp reference at
the model level.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pipeline
from repro.core.dispatch import BACKENDS, resolve_backend
from repro.core.pipeline import METHODS, quantize_tree
from repro.quant.qtypes import QuantizedTensor


def _tree(rng):
    """2-D dense (two sharing a bucket), 3-D expert, 4-D conv, non-kernels."""
    def w(*shape):
        return jnp.asarray(rng.normal(size=shape).astype(np.float32))
    return {
        "blk0": {"attn": {"w": w(24, 32)},
                 "norm": {"gain": jnp.ones((24,), jnp.float32)}},
        "blk1": {"attn": {"w": w(24, 32)}},          # same bucket as blk0
        "head": {"w": w(48, 16)},                    # its own bucket
        "moe": {"w": w(2, 16, 8)},                   # (E, in, out) expert
        "conv": {"w_conv": w(3, 3, 4, 8)},           # (KH, KW, in, out)
        "emb": {"table": w(10, 24)},                 # never quantized
    }


def _qts(tree):
    leaves = jax.tree_util.tree_leaves(
        tree, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    return [l for l in leaves if isinstance(l, QuantizedTensor)]


@pytest.mark.parametrize("method", METHODS)
def test_batched_bit_exact_vs_serial(rng, method):
    src = _tree(rng)
    t_b, rep_b = quantize_tree(src, method=method, bits=4, group_size=16,
                               batched=True, backend="ref")
    t_s, rep_s = quantize_tree(src, method=method, bits=4, group_size=16,
                               batched=False)
    qb, qs = _qts(t_b), _qts(t_s)
    assert len(qb) == len(qs) == 5
    for a, b in zip(qb, qs):
        assert a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a.codes()),
                                      np.asarray(b.codes()))
        np.testing.assert_array_equal(np.asarray(a.scale),
                                      np.asarray(b.scale))
    assert len(rep_b.layers) == len(rep_s.layers) == 5
    # two same-shape dense layers share one bucket
    assert len(rep_b.buckets) == 4
    assert rep_b.total_millis > 0


@pytest.mark.parametrize("method", ("rtn", "squant"))
def test_batched_fake_quant_matches_serial(rng, method):
    src = _tree(rng)
    t_b, _ = quantize_tree(src, method=method, bits=4, group_size=16,
                           dequantize=True, batched=True)
    t_s, _ = quantize_tree(src, method=method, bits=4, group_size=16,
                           dequantize=True, batched=False)
    for a, b in zip(jax.tree_util.tree_leaves(t_b),
                    jax.tree_util.tree_leaves(t_s)):
        assert a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_batched_single_sync_serial_per_layer(rng, monkeypatch):
    calls = []
    real = pipeline._sync
    monkeypatch.setattr(pipeline, "_sync",
                        lambda x: (calls.append(1), real(x))[1])
    quantize_tree(_tree(rng), method="squant", bits=4, group_size=16,
                  batched=True)
    assert len(calls) == 1                    # ONE device sync for the tree
    calls.clear()
    quantize_tree(_tree(rng), method="squant", bits=4, group_size=16,
                  batched=False)
    assert len(calls) == 5                    # legacy: one per quantized leaf


def test_interpret_backend_matches_ref(rng):
    """Pallas kernel body (interpret mode) serves the model-level path."""
    def w(*shape):
        return jnp.asarray(rng.normal(size=shape).astype(np.float32))
    src = {"a": {"w": w(16, 8)}, "b": {"w": w(16, 8)},
           "conv": {"w_conv": w(2, 2, 4, 8)}}
    for method in ("squant", "squant_ek", "squant_e"):
        t_r, _ = quantize_tree(src, method=method, bits=4, group_size=8,
                               backend="ref")
        t_i, rep_i = quantize_tree(src, method=method, bits=4, group_size=8,
                                   backend="interpret")
        assert rep_i.backend == "interpret"
        for a, b in zip(_qts(t_r), _qts(t_i)):
            np.testing.assert_array_equal(np.asarray(a.codes()),
                                          np.asarray(b.codes()))


def test_backend_resolution():
    assert resolve_backend("ref") == "ref"
    assert resolve_backend("interpret") == "interpret"
    assert resolve_backend("auto") in ("ref", "pallas")
    assert set(BACKENDS) == {"auto", "ref", "pallas", "interpret"}
    with pytest.raises(ValueError):
        quantize_tree({"w": jnp.ones((4, 4))}, backend="cuda")


def test_bucket_chunking_bit_exact(rng, monkeypatch):
    """A bucket whose stack exceeds the byte cap splits into chunks; results
    stay bit-exact and the tree still syncs once."""
    src = _tree(rng)
    monkeypatch.setattr(pipeline, "_MAX_STACK_BYTES",
                        24 * 32 * 4 + 1)      # one (24,32) f32 layer per chunk
    calls = []
    real = jax.block_until_ready
    monkeypatch.setattr(pipeline, "_sync",
                        lambda x: (calls.append(1), real(x))[1])
    t_b, rep_b = quantize_tree(src, method="squant", bits=4, group_size=16,
                               batched=True)
    assert len(calls) == 1
    # the (32,24)x2 dense bucket split into two singleton chunks
    assert len(rep_b.buckets) == 5
    t_s, _ = quantize_tree(src, method="squant", bits=4, group_size=16,
                           batched=False)
    for a, b in zip(_qts(t_b), _qts(t_s)):
        np.testing.assert_array_equal(np.asarray(a.codes()),
                                      np.asarray(b.codes()))


def test_report_breakdown(rng):
    _, rep = quantize_tree(_tree(rng), method="squant", bits=4, group_size=16)
    assert rep.dispatch_millis > 0 and rep.sync_millis >= 0
    assert rep.total_millis >= rep.dispatch_millis
    assert sum(b.num_layers for b in rep.buckets) == len(rep.layers)
    assert "buckets" in rep.summary()
    for lr in rep.layers:
        assert lr.bucket            # every layer names its bucket
