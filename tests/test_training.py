"""Training runtime tests: optimizer, microbatching, learning on a
low-entropy stream, checkpoint/restart fault tolerance, straggler monitor."""
import os
import subprocess
import sys
import tempfile
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import markov_batches, synthetic_batches
from repro.models.model import build_model
from repro.runtime.monitor import StepMonitor
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.training.train_loop import Trainer, TrainerConfig, make_train_step


def _tiny_model():
    import dataclasses
    cfg = get_config("granite-3-8b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32", n_layers=2, d_model=32,
                              n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
                              vocab=64)
    return build_model(cfg), cfg


def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0]), "b": jnp.asarray([2.0])}
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, decay_steps=100,
                      weight_decay=0.0, clip_norm=None)
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < l0 * 0.1


def test_microbatched_step_matches_full_batch():
    model, cfg = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    ocfg = AdamWConfig(warmup_steps=0, clip_norm=None, weight_decay=0.0)
    batch = next(synthetic_batches(8, 16, cfg.vocab))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    s1 = jax.jit(make_train_step(model, ocfg, microbatches=1))
    s4 = jax.jit(make_train_step(model, ocfg, microbatches=4))
    p1, _, m1 = s1(params, opt, batch)
    p4, _, m4 = s4(params, opt, batch)
    # same data, same params: losses equal; updates equal up to accumulation
    # order (fp32 summation) — tight tolerance
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=2e-5)


def test_training_learns_markov_stream():
    model, cfg = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=5, decay_steps=80)
    step = jax.jit(make_train_step(model, ocfg))
    it = (jax.tree_util.tree_map(jnp.asarray, b)
          for b in markov_batches(8, 32, cfg.vocab, seed=1))
    losses = []
    for i in range(80):
        params, opt, m = step(params, opt, next(it))
        losses.append(float(m["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first * 0.7, f"no learning: {first:.3f} → {last:.3f}"
    assert last < np.log(cfg.vocab) * 0.8   # below uniform entropy


def test_straggler_monitor():
    mon = StepMonitor(factor=3.0, warmup=2)
    for _ in range(10):
        mon.record(0.1)
    assert not mon.flagged
    assert mon.record(1.0)          # 10× EWMA → flagged
    assert mon.flagged
    e = mon.ewma
    mon.record(0.1)
    assert abs(mon.ewma - e) < 0.05  # straggler did not poison the EWMA


_TRAIN_SCRIPT = textwrap.dedent("""
    import sys, dataclasses
    import jax, jax.numpy as jnp
    sys.path.insert(0, "{src}")
    sys.path.insert(0, "{tests}")
    from repro.configs import get_config
    from repro.data.synthetic import markov_batches
    from repro.models.model import build_model
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_loop import Trainer, TrainerConfig

    cfg = get_config("granite-3-8b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32", n_layers=2, d_model=32,
                              n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
                              vocab=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tr = Trainer(model, AdamWConfig(lr=1e-3, warmup_steps=0),
                 TrainerConfig(total_steps={steps}, checkpoint_every=5,
                               checkpoint_dir="{ckpt}", log_every=1,
                               async_checkpoint=False))
    it = (jax.tree_util.tree_map(jnp.asarray, b)
          for b in markov_batches(4, 16, cfg.vocab, seed=1))
    params, opt, info = tr.run(params, it)
    print("FINAL_STEP", len(info["history"]))
""")


@pytest.mark.slow
def test_kill_and_restart_resumes():
    """Fault tolerance: kill training mid-run; restart resumes from the
    newest committed checkpoint and finishes."""
    with tempfile.TemporaryDirectory() as tmp:
        ckpt = os.path.join(tmp, "ckpt")
        script = _TRAIN_SCRIPT.format(
            src=os.path.join(os.path.dirname(__file__), "..", "src"),
            tests=os.path.dirname(__file__), ckpt=ckpt, steps=40)
        env = dict(os.environ)
        proc = subprocess.Popen([sys.executable, "-c", script],
                                stdout=subprocess.PIPE,
                                stderr=subprocess.STDOUT, env=env, text=True)
        # let it get through some steps + at least one checkpoint, then kill
        deadline = time.time() + 120
        saw_step = False
        while time.time() < deadline:
            line = proc.stdout.readline()
            if "step 12" in line:
                saw_step = True
                break
            if proc.poll() is not None:
                break
        assert saw_step, "training never reached step 12"
        proc.kill()
        proc.wait()
        # a committed checkpoint must exist
        from repro.checkpoint.checkpointer import Checkpointer
        ck = Checkpointer(ckpt)
        steps = ck.list_steps()
        assert steps and steps[-1] >= 5
        # restart: must resume from >= the checkpoint, not from zero
        out = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, env=env,
                             timeout=300)
        assert "resumed from step" in out.stdout, out.stdout[-2000:]
        assert "FINAL_STEP" in out.stdout


def test_preemption_checkpoint(tmp_path):
    """SIGTERM-style preemption: trainer commits a checkpoint and exits."""
    model, cfg = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    tr = Trainer(model, AdamWConfig(lr=1e-3, warmup_steps=0),
                 TrainerConfig(total_steps=100, checkpoint_every=1000,
                               checkpoint_dir=str(tmp_path),
                               async_checkpoint=False, log_every=50))
    it = (jax.tree_util.tree_map(jnp.asarray, b)
          for b in synthetic_batches(4, 16, cfg.vocab))

    def hook(step, p, m):
        if step == 3:
            tr._preempted = True    # simulate SIGTERM delivery

    tr.run(params, it, step_hook=hook)
    from repro.checkpoint.checkpointer import Checkpointer
    steps = Checkpointer(str(tmp_path)).list_steps()
    assert steps == [4]
