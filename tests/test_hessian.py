"""Tests for the Hessian decomposition (Algorithm 3) and the
approximation-precision analysis (Appendix A.3 / Table 6)."""
import numpy as np
import pytest

from repro.core.hessian import (approx_objective, approximation_precision,
                                decompose, exact_objective, precise_objective,
                                reconstruction, second_moment)


def _correlated_inputs(rng, samples, n, k):
    """ReLU-like inputs whose E[xxᵀ] has the paper's structure (Appendix
    A.1): a channel-common floor (non-negative activations with large means),
    a per-kernel shared component (spatially correlated feature maps), and
    element noise with *decaying* within-kernel correlation so the E+K+C
    decomposition has a genuine off-diagonal residual."""
    common = rng.normal(size=(samples, 1, 1))
    kern = rng.normal(size=(samples, n, 1))
    elem = rng.normal(size=(samples, n, k + 4))
    smooth = np.array([0.3, 0.7, 1.0, 0.7, 0.3])
    sm = np.stack([elem[..., i:i + k] for i in range(5)], 0)
    elem = (sm * smooth[:, None, None, None]).sum(0) / np.sqrt(
        (smooth ** 2).sum())
    x = 0.3 * common + 0.8 * kern + 0.45 * elem + 0.5
    return np.maximum(x, 0).reshape(samples, n * k)


def test_decomposition_positive_and_psd(rng):
    x = _correlated_inputs(rng, 2000, 8, 9)
    h = second_moment(x)
    co = decompose(h, group_size=9)
    assert co.c > 0
    assert np.all(co.k > 0)
    assert np.all(co.e > 0)
    # approximation preserves the diagonal exactly (Algorithm 3 line 8)
    rec = reconstruction(co)
    np.testing.assert_allclose(np.diag(rec), np.diag(np.abs(h)), rtol=1e-10)
    # E+K+C is PSD: all-ones blocks are PSD, diagonal positive
    evals = np.linalg.eigvalsh(rec)
    assert evals.min() > -1e-8


def test_objectives_agree_on_structured_h(rng):
    """When H is exactly E+K+C, precise_objective == δHδᵀ."""
    x = _correlated_inputs(rng, 500, 4, 8)
    h = second_moment(x)
    co = decompose(h, group_size=8)
    rec = reconstruction(co)
    d = rng.normal(size=32)
    np.testing.assert_allclose(precise_objective(d, co),
                               exact_objective(d, rec), rtol=1e-9)


def test_approx_objective_is_unit_coeff_case(rng):
    d = rng.normal(size=24)
    got = approx_objective(d, group_size=8)
    dg = d.reshape(3, 8)
    want = (d ** 2).sum() + (dg.sum(1) ** 2).sum() + d.sum() ** 2
    np.testing.assert_allclose(got, want, rtol=1e-12)


@pytest.mark.parametrize("bits", [3, 4])
def test_approximation_precision_high(rng, bits):
    """Table 6 reproduction at container scale: the data-free objective's
    flip decisions agree with the data-driven Eq. (6) for the vast majority
    of flips (paper reports 93.6% E&K / 97.8% E&K&C overall)."""
    n, k = 16, 9
    x = _correlated_inputs(rng, 4000, n, k)
    w = rng.normal(size=(32, n * k)).astype(np.float32) * 0.2
    rep = approximation_precision(w, x, bits=bits, group_size=k)
    assert rep.flipped > 100
    assert rep.ap > 0.9, f"AP too low: {rep.ap:.3f} ({rep.by_stage})"
    assert rep.ap_exact > 0.9, f"exact-H AP too low: {rep.ap_exact:.3f}"
    assert rep.ap_inorder > 0.5


def test_ap_uses_no_weight_gradients(rng):
    """The AP analysis consumes activation samples only — the flip log comes
    from the data-free reference; this asserts the quantizer output is
    unchanged by the choice of activation samples."""
    n, k = 8, 4
    w = rng.normal(size=(8, n * k)).astype(np.float32)
    x1 = _correlated_inputs(rng, 256, n, k)
    x2 = _correlated_inputs(np.random.default_rng(7), 256, n, k)
    r1 = approximation_precision(w, x1, bits=4, group_size=k)
    r2 = approximation_precision(w, x2, bits=4, group_size=k)
    assert r1.flipped == r2.flipped  # same flips, only scoring differs
