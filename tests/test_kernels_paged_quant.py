"""Fused int8 dequant paged-attention kernel tests: interpret-mode parity
vs the jnp reference on mixed lengths and GQA head ratios, trash-block /
masked-column exactness with poisoned codes AND scales, closeness to the
fp paged oracle when the pools come from ``_quant_tok``, and the
quantizer's own hardening properties (all-zero rows, extreme magnitudes,
round-trip bound, vmap/jit friendliness, no int8 wrap)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import paged_attention_ref
from repro.kernels.paged_attention_quant import (paged_attention_quant,
                                                 paged_attention_quant_ref)
from repro.models.attention import _quant_tok


def _rand_quant_pools(key, nblocks, bs, kv, d):
    """fp pools quantized per-(position, head) with the serving quantizer
    (the exact write path both backends use)."""
    k1, k2 = jax.random.split(key)
    k_fp = jax.random.normal(k1, (nblocks, bs, kv, d), jnp.float32)
    v_fp = jax.random.normal(k2, (nblocks, bs, kv, d), jnp.float32)
    kq, ks = _quant_tok(k_fp)
    vq, vs = _quant_tok(v_fp)
    return k_fp, v_fp, kq, ks, vq, vs


# ---------------------------------------------------------------------------
# interpret-mode parity vs the jnp reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rep", [1, 2, 4])      # MQA → GQA head ratios
def test_quant_kernel_matches_ref(rep):
    key = jax.random.PRNGKey(0)
    b, kv, d, bs, nb_slot, nblocks = 3, 2, 16, 4, 6, 20
    h = kv * rep
    ks_ = jax.random.split(key, 3)
    q = jax.random.normal(ks_[0], (b, h, d), jnp.float32)
    _, _, kq, ksc, vq, vsc = _rand_quant_pools(ks_[1], nblocks, bs, kv, d)
    bt = jax.random.randint(ks_[2], (b, nb_slot), 1, nblocks) \
        .astype(jnp.int32)
    lengths = jnp.asarray([0, 7, 21], jnp.int32)   # mixed fills
    scale = 1.0 / np.sqrt(d)
    ref = paged_attention_quant_ref(q, kq, vq, ksc, vsc, bt, lengths,
                                    scale=scale)
    ker = paged_attention_quant(q, kq, vq, ksc, vsc, bt, lengths,
                                scale=scale, use_pallas="interpret")
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_quant_ref_masks_trash_columns_and_scales():
    """Columns past a row's length must contribute exactly zero even when
    their codes AND scales are poisoned — the mask, not zero-initialized
    scales, is what protects never-written pool positions."""
    key = jax.random.PRNGKey(1)
    b, kv, d, bs, nb_slot, nblocks = 2, 1, 8, 4, 3, 8
    ks_ = jax.random.split(key, 3)
    q = jax.random.normal(ks_[0], (b, kv, d), jnp.float32)
    _, _, kq, ksc, vq, vsc = _rand_quant_pools(ks_[1], nblocks, bs, kv, d)
    bt = jax.random.randint(ks_[2], (b, nb_slot), 1, nblocks) \
        .astype(jnp.int32)
    lengths = jnp.asarray([2, 9], jnp.int32)
    # poison every pool position past each row's length
    dead = np.ones((nblocks, bs), bool)
    bt_np, ln_np = np.asarray(bt), np.asarray(lengths)
    for r in range(b):
        for j in range(nb_slot):
            for o in range(bs):
                if j * bs + o <= ln_np[r]:
                    dead[bt_np[r, j], o] = False
    assert dead.any()
    poison_c = jnp.where(jnp.asarray(dead)[:, :, None, None],
                         jnp.full_like(kq, 127), kq)
    poison_v = jnp.where(jnp.asarray(dead)[:, :, None, None],
                         jnp.full_like(vq, -127), vq)
    poison_ks = jnp.where(jnp.asarray(dead)[:, :, None],
                          jnp.full_like(ksc, 1e6), ksc)
    poison_vs = jnp.where(jnp.asarray(dead)[:, :, None],
                          jnp.full_like(vsc, 1e6), vsc)
    # each implementation is compared against ITS OWN unpoisoned output
    # (ref vs interpret only agree to float tolerance, masking is exact)
    for fn, kwargs in ((paged_attention_quant_ref, {}),
                       (paged_attention_quant,
                        {"use_pallas": "interpret"})):
        base = fn(q, kq, vq, ksc, vsc, bt, lengths, scale=0.35, **kwargs)
        out = fn(q, poison_c, poison_v, poison_ks, poison_vs, bt, lengths,
                 scale=0.35, **kwargs)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(base))


def test_quant_ref_close_to_fp_oracle():
    """Quantized pools built by ``_quant_tok`` must reproduce the fp paged
    oracle within int8 round-trip noise — the closeness the serving-level
    0.98 greedy-agreement budget rides on."""
    key = jax.random.PRNGKey(2)
    b, kv, rep, d, bs, nb_slot, nblocks = 2, 2, 2, 16, 4, 4, 12
    h = kv * rep
    ks_ = jax.random.split(key, 3)
    q = jax.random.normal(ks_[0], (b, h, d), jnp.float32)
    k_fp, v_fp, kq, ksc, vq, vsc = _rand_quant_pools(ks_[1], nblocks, bs,
                                                     kv, d)
    bt = jax.random.randint(ks_[2], (b, nb_slot), 1, nblocks) \
        .astype(jnp.int32)
    lengths = jnp.asarray([5, 15], jnp.int32)
    scale = 1.0 / np.sqrt(d)
    fp = paged_attention_ref(q, k_fp, v_fp, bt, lengths, scale=scale)
    qn = paged_attention_quant_ref(q, kq, vq, ksc, vsc, bt, lengths,
                                   scale=scale)
    err = np.abs(np.asarray(fp) - np.asarray(qn)).max()
    ref_mag = np.abs(np.asarray(fp)).max()
    assert err <= 0.05 * ref_mag, (err, ref_mag)


# ---------------------------------------------------------------------------
# _quant_tok hardening (satellite: all-zero rows, extremes, vmap/jit)
# ---------------------------------------------------------------------------

def test_quant_tok_round_trip_extreme_magnitudes():
    """Property over extreme rows: round-trip error <= 0.5 * scale per
    element, no NaN/Inf, and codes never wrap past +/-127."""
    rows = np.stack([
        np.zeros(8, np.float32),                     # all-zero row
        np.full(8, 1e-30, np.float32),               # below the scale floor
        np.full(8, -1e-30, np.float32),
        np.linspace(-1e30, 1e30, 8).astype(np.float32),
        np.asarray([1e30] + [0.0] * 7, np.float32),  # one huge outlier
        np.asarray([-1e-6, 1e-6] * 4, np.float32),   # at the floor
        np.linspace(-3.0, 3.0, 8).astype(np.float32),
    ])
    x = jnp.asarray(rows)[None, :, None, :]          # (1, S, KV=1, D)
    codes, scale = _quant_tok(x)
    codes_np, scale_np = np.asarray(codes, np.int32), np.asarray(scale)
    assert np.isfinite(scale_np).all()
    assert codes_np.min() >= -127 and codes_np.max() <= 127
    deq = codes_np.astype(np.float64) * scale_np[..., None]
    assert np.isfinite(deq).all()
    err = np.abs(deq - np.asarray(x, np.float64))
    assert (err <= 0.5 * scale_np[..., None] + 1e-38).all(), err.max()


def test_quant_tok_zero_rows_exact():
    codes, scale = _quant_tok(jnp.zeros((2, 3, 2, 4)))
    assert np.all(np.asarray(codes) == 0)
    assert np.isfinite(np.asarray(scale)).all()
    deq = np.asarray(codes, np.float32) * np.asarray(scale)[..., None]
    np.testing.assert_array_equal(deq, np.zeros((2, 3, 2, 4), np.float32))


def test_quant_tok_vmap_jit_any_leading_shape():
    """One quantizer for both backends: contiguous writes (B, S, KV, D)
    rows, the paged decode path quantizes (B, 1, KV, D) — and it must
    compose with vmap/jit without shape-specific branches."""
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 5, 2, 8), jnp.float32)
    c_direct, s_direct = _quant_tok(x)
    c_vmap, s_vmap = jax.jit(jax.vmap(_quant_tok))(x)
    # jit fusion may reorder the abs-max reduction by ~1 ulp, so compare
    # the dequantized values (codes can flip only at exact .5 boundaries)
    assert c_vmap.dtype == jnp.int8 and s_vmap.shape == s_direct.shape
    np.testing.assert_allclose(
        np.asarray(c_vmap, np.float32) * np.asarray(s_vmap)[..., None],
        np.asarray(c_direct, np.float32) * np.asarray(s_direct)[..., None],
        rtol=1e-5, atol=1e-6)
    # 3D leading shape (pool-shaped input) works too
    c_pool, s_pool = jax.jit(_quant_tok)(x.reshape(15, 2, 8))
    assert c_pool.dtype == jnp.int8 and s_pool.shape == (15, 2)
    np.testing.assert_allclose(
        np.asarray(c_pool, np.float32) * np.asarray(s_pool)[..., None],
        (np.asarray(c_direct, np.float32)
         * np.asarray(s_direct)[..., None]).reshape(15, 2, 8),
        rtol=1e-5, atol=1e-6)
