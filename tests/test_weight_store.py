"""Versioned weight store + zero-downtime reload tests.

Covers: version/swap semantics (swaps land ONLY at decode-round
boundaries — a version staged mid-round never tears the in-flight round),
background staging (latest request wins), the checkpoint watcher (fp
checkpoints re-quantized on the fly, quantized checkpoints loaded natively,
torn/corrupt step dirs skipped, metadata mismatches rejected), and a live
multi-round reload with zero failed requests.
"""
import dataclasses
import os
import threading
import time

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.models.model import build_model
from repro.quant.apply import quantize_params_serving
from repro.serving.engine import Request, ServeConfig, ServeEngine
from repro.serving.weights import WeightStore


def _tiny(seed=0):
    cfg = get_config("granite-3-8b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32", n_layers=2, d_model=32,
                              n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
                              vocab=64)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(seed)), cfg


def _reqs(n, max_new=4):
    return [Request(prompt=[1 + i % 5, 2, 3], max_new_tokens=max_new,
                    request_id=i) for i in range(n)]


def test_initial_version_and_properties():
    model, params, _ = _tiny()
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_len=32,
                                  quantize_weights="squant", weight_bits=8))
    assert eng.store.version == 1
    assert eng.quant_report is not None and eng.quant_report.layers
    assert eng.params is eng.store.current.params
    st = eng.store.stats()
    assert st["version"] == 1 and st["swaps"] == 0
    assert st["source"] == "init" and not st["errors"]
    out = eng.generate(_reqs(2))
    assert all(o.weights_version == 1 for o in out)
    assert all(o.swap_ms >= 0.0 for o in out)


def test_swap_never_lands_mid_round():
    """A version staged during decode becomes visible only at the next
    round boundary: round 1 serves v1 end-to-end (token-identical to an
    engine that never reloads), round 2 serves v2."""
    model, params, _ = _tiny(0)
    _, params2, _ = _tiny(1)
    scfg = ServeConfig(max_batch=2, max_len=32, quantize_weights="squant",
                       weight_bits=8)
    eng = ServeEngine(model, params, scfg)
    control = ServeEngine(model, params, scfg)

    fired = []
    orig_decode = eng._decode

    def hooked(p, cur, cache):
        if not fired:
            fired.append(True)
            # stage synchronously MID-ROUND: fully built before round ends
            eng.store.stage(fp_params=params2, source="midround",
                            block=True)
        return orig_decode(p, cur, cache)

    eng._decode = hooked
    outs = eng.generate(_reqs(4, max_new=4))        # 2 rounds of 2
    ctrl = control.generate(_reqs(4, max_new=4))
    assert fired, "decode hook never ran"
    r1, r2 = outs[:2], outs[2:]
    assert all(o.weights_version == 1 for o in r1)
    assert all(o.weights_version == 2 for o in r2)
    # round 1 never saw the staged tree: bit-identical to the no-reload run
    for a, b in zip(r1, ctrl[:2]):
        assert a.tokens == b.tokens
    log = eng.stats()["round_log"]
    assert [e["version"] for e in log] == [1, 2]
    assert eng.store.swap_count == 1
    assert all("swap_ms" in e and "prefill_ms" in e and "decode_ms" in e
               for e in log)


def test_background_stage_latest_wins():
    built = []

    def slow_quantize(tree):
        time.sleep(0.05)
        built.append(tree["tag"])
        return tree, None

    store = WeightStore(slow_quantize, fp_params={"tag": 0,
                                                  "w": jnp.zeros(2)})
    for i in (1, 2, 3):
        store.stage(fp_params={"tag": i, "w": jnp.zeros(2)},
                    source=f"s{i}")
    assert store.wait_staged(timeout=10)
    deadline = time.monotonic() + 10
    while time.monotonic() < deadline:
        live, _ = store.acquire()
        if live.params["tag"] == 3:
            break
        time.sleep(0.01)
    assert live.params["tag"] == 3          # newest request won
    assert store.version == live.version
    assert not store.errors
    store.close()


def test_watcher_quantizes_fp_checkpoints_on_the_fly(tmp_path):
    model, params, _ = _tiny(0)
    _, params2, _ = _tiny(1)
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_len=32,
                                  quantize_weights="squant", weight_bits=8))
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, params2, {"m": jnp.zeros(1)})     # training-style fp save
    expect = {"quantize_weights": "squant", "weight_bits": 8}
    assert eng.store.poll_checkpoints(ck, expect=expect) == 1
    out = eng.generate(_reqs(2))
    assert all(o.weights_version == 2 for o in out)
    cur = eng.store.current
    assert cur.source == "ckpt:1" and cur.step == 1
    assert cur.report is not None            # re-quantized via quantize_tree
    # same step polls as a no-op
    assert eng.store.poll_checkpoints(ck, expect=expect) is None


def test_watcher_loads_quantized_checkpoints_natively(tmp_path):
    model, params, _ = _tiny(0)
    _, params2, _ = _tiny(1)
    qtree, meta = quantize_params_serving(params2, 8, "squant")
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save_serving(5, qtree, quant_meta=meta)
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_len=32,
                                  quantize_weights="squant", weight_bits=8))
    assert eng.store.poll_checkpoints(
        ck, expect={"quantize_weights": "squant", "weight_bits": 8}) == 5
    out = eng.generate(_reqs(2))
    assert all(o.weights_version == 2 for o in out)
    assert all(len(o.tokens) == 4 for o in out)


def _break_step(dirname, mode):
    if mode == "torn":
        os.remove(os.path.join(dirname, "COMMITTED"))
    else:
        with open(os.path.join(dirname, "index.json"), "w") as f:
            f.write('{"step": 3, "trees": {')       # truncated json


@pytest.mark.parametrize("kind", ["fp", "quantized"])
def test_watcher_skips_torn_and_corrupt_steps(tmp_path, kind):
    model, params, _ = _tiny(0)
    _, params2, _ = _tiny(1)
    ck = Checkpointer(str(tmp_path), async_save=False)

    def save(step, tree):
        if kind == "fp":
            ck.save_serving(step, tree)
        else:
            q, m = quantize_params_serving(tree, 8, "squant")
            ck.save_serving(step, q, quant_meta=m)

    save(1, params)
    save(2, params2)
    save(3, params2)
    _break_step(str(tmp_path / "step_00000002"), "torn")
    _break_step(str(tmp_path / "step_00000003"), "corrupt")
    assert ck.list_steps() == [1]
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_len=32,
                                  quantize_weights="squant", weight_bits=8))
    expect = {"quantize_weights": "squant", "weight_bits": 8}
    assert eng.store.poll_checkpoints(ck, expect=expect) == 1
    assert not eng.store.errors
    # a later valid step is picked up past the broken ones
    save(4, params2)
    assert eng.store.poll_checkpoints(ck, expect=expect) == 4
    out = eng.generate(_reqs(2))
    assert all(o.weights_version == 3 for o in out)     # init + 2 reloads


def test_watcher_rejects_meta_mismatch(tmp_path):
    model, params, _ = _tiny(0)
    qtree, meta = quantize_params_serving(params, 4, "squant")
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save_serving(1, qtree, quant_meta=meta)
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_len=32,
                                  quantize_weights="squant", weight_bits=8))
    expect = {"quantize_weights": "squant", "weight_bits": 8}
    assert eng.store.poll_checkpoints(ck, expect=expect) is None
    assert eng.store.version == 1                      # nothing swapped in
    errs = eng.store.stats()["errors"]
    assert errs and "mismatch" in errs[0]
    # the bad step is remembered, not retried forever
    assert eng.store.poll_checkpoints(ck, expect=expect) is None
    assert len(eng.store.errors) == 1


def test_watcher_retries_transient_failures(tmp_path):
    """A restore that fails transiently (I/O hiccup) is retried on later
    polls — only metadata mismatches are permanent."""
    model, params, _ = _tiny(0)
    _, params2, _ = _tiny(1)
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, params2, {"m": jnp.zeros(1)})
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_len=32,
                                  quantize_weights="squant", weight_bits=8))
    orig, flaked = ck.restore_serving, []

    def flaky(*a, **kw):
        if not flaked:
            flaked.append(True)
            raise OSError("disk hiccup")
        return orig(*a, **kw)

    ck.restore_serving = flaky
    expect = {"quantize_weights": "squant", "weight_bits": 8}
    assert eng.store.poll_checkpoints(ck, expect=expect) is None
    assert "retries left" in eng.store.errors[-1]
    assert eng.store.poll_checkpoints(ck, expect=expect) == 1   # retried
    assert eng.store.wait_staged(version=1, timeout=30)
    # success clears the retry budget: same step is not re-staged
    assert eng.store.poll_checkpoints(ck, expect=expect) is None


def test_live_reload_zero_failed_requests(tmp_path):
    """Acceptance: a live reload during multi-round generation completes
    with zero failed/corrupted requests and the swapped-in version is
    observable in engine stats."""
    model, params, _ = _tiny(0)
    _, params2, _ = _tiny(1)
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_len=32,
                                  quantize_weights="squant", weight_bits=8))
    eng.watch_checkpoints(str(tmp_path), poll_s=0.02)
    ck = Checkpointer(str(tmp_path), async_save=False)

    def writer():
        time.sleep(0.05)
        ck.save(1, params2, {"m": jnp.zeros(1)})

    th = threading.Thread(target=writer)
    th.start()
    outs = []
    deadline = time.monotonic() + 60
    while time.monotonic() < deadline:
        outs.extend(eng.generate(_reqs(4, max_new=3)))     # 2 rounds/call
        if outs[-1].weights_version >= 2:
            break
    th.join()
    assert outs[-1].weights_version >= 2, "reload never landed"
    # zero failed/corrupted requests: every completion fully decoded
    assert all(len(o.tokens) == 3 for o in outs)
    versions = [e["version"] for e in eng.stats()["round_log"]]
    assert versions == sorted(versions)                     # monotonic
    st = eng.stats()["weights"]
    assert st["swaps"] >= 1 and st["version"] >= 2
    assert st["source"] == "ckpt:1"
    assert not st["errors"]
    eng.close()
    assert not eng.store.stats()["watching"]


def test_engine_from_prebuilt_qdict_store():
    """An externally staged serving tree (native quantized format) drives
    the engine without any fp params or quantize call."""
    model, params, _ = _tiny(0)
    qtree, _ = quantize_params_serving(params, 8, "squant")
    store = WeightStore(serving_params=qtree, source="prequantized")
    eng = ServeEngine(model, cfg=ServeConfig(max_batch=2, max_len=32),
                      store=store)
    out = eng.generate(_reqs(2))
    assert all(len(o.tokens) == 4 for o in out)
    assert eng.store.current.source == "prequantized"
