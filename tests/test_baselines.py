"""Tests for the data-free baselines (RTN / DFQ equalization / bias
correction / ZeroQ-style synthesis / AdaRound)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines
from repro.quant.scales import compute_scale


def test_rtn_matches_manual(rng):
    w = rng.normal(size=(8, 64)).astype(np.float32)
    qt = baselines.rtn(jnp.asarray(w), bits=4)
    s = np.asarray(qt.scale)
    np.testing.assert_array_equal(np.asarray(qt.codes()),
                                  np.clip(np.round(w / s), -7, 7))


def test_mse_scale_beats_max_scale_on_outliers(rng):
    w = rng.normal(size=(16, 512)).astype(np.float32)
    w[:, 0] *= 30.0  # outlier per row
    wj = jnp.asarray(w)
    for bits in (3, 4):
        s_max = compute_scale(wj, bits, "max")
        s_mse = compute_scale(wj, bits, "mse")
        qmax = 2 ** (bits - 1) - 1

        def err(s):
            q = jnp.clip(jnp.round(wj / s), -qmax, qmax)
            return float(jnp.mean((q * s - wj) ** 2))

        assert err(s_mse) < err(s_max)


def test_equalization_preserves_function(rng):
    """ReLU positive homogeneity: W2·relu(W1 x) invariant under equalization."""
    w1 = rng.normal(size=(32, 16)).astype(np.float32)
    w2 = rng.normal(size=(8, 32)).astype(np.float32)
    x = rng.normal(size=(64, 16)).astype(np.float32)
    e1, e2, s = baselines.equalize_pair(jnp.asarray(w1), jnp.asarray(w2))
    y0 = w2 @ np.maximum(w1 @ x.T, 0)
    y1 = np.asarray(e2) @ np.maximum(np.asarray(e1) @ x.T, 0)
    np.testing.assert_allclose(y1, y0, rtol=1e-4, atol=1e-4)
    # ranges actually equalized
    r1 = np.abs(np.asarray(e1)).max(1)
    r2 = np.abs(np.asarray(e2)).max(0)
    np.testing.assert_allclose(r1, r2, rtol=1e-3)


def test_equalization_reduces_rtn_error(rng):
    """Pathological per-channel ranges: equalization + per-tensor RTN beats
    plain per-tensor RTN (the regime DFQ equalization is designed for)."""
    w1 = rng.normal(size=(32, 16)).astype(np.float32)
    w1 *= np.logspace(-2, 1, 32)[:, None].astype(np.float32)  # wild ranges
    w2 = rng.normal(size=(8, 32)).astype(np.float32)
    x = rng.normal(size=(128, 16)).astype(np.float32)
    y_ref = np.maximum(w1 @ x.T, 0).T @ w2.T

    def pt(a):
        s = float(np.abs(a).max() / 7.0)
        return np.clip(np.round(a / s), -7, 7) * s

    def quant_err(a, b):
        y = np.maximum(pt(a) @ x.T, 0).T @ pt(b).T
        return float(np.mean((y - y_ref) ** 2))

    e1, e2, _ = baselines.equalize_pair(jnp.asarray(w1), jnp.asarray(w2))
    assert quant_err(np.asarray(e1), np.asarray(e2)) < quant_err(w1, w2)


def test_bias_correction_zeroes_expected_shift(rng):
    w = rng.normal(size=(8, 32)).astype(np.float32)
    wq = np.asarray(baselines.rtn(jnp.asarray(w), bits=3).dequantize())
    mu = rng.normal(size=32).astype(np.float32)
    corr = np.asarray(baselines.bias_correction(
        jnp.asarray(w), jnp.asarray(wq), jnp.asarray(mu)))
    shift = (wq - w) @ mu + corr
    np.testing.assert_allclose(shift, 0.0, atol=1e-5)


def test_synthesize_inputs_matches_stats(rng):
    key = jax.random.PRNGKey(0)
    target = jnp.asarray([0.0, 1.0])

    def stat_fn(x):
        return jnp.stack([jnp.mean(x), jnp.var(x)])

    x = baselines.synthesize_inputs(stat_fn, target, (32, 16), key, iters=200)
    s = np.asarray(stat_fn(x))
    assert abs(s[0]) < 0.05 and abs(s[1] - 1.0) < 0.1


@pytest.mark.slow
def test_adaround_beats_rtn_on_output_mse(rng):
    w = rng.normal(size=(16, 64)).astype(np.float32)
    x = rng.normal(size=(128, 64)).astype(np.float32)
    y_ref = x @ w.T
    q_rtn = np.asarray(baselines.rtn(jnp.asarray(w), bits=3).dequantize())
    q_ada = np.asarray(baselines.adaround(
        jnp.asarray(w), jnp.asarray(x), bits=3, iters=150).dequantize())
    err_rtn = np.mean((x @ q_rtn.T - y_ref) ** 2)
    err_ada = np.mean((x @ q_ada.T - y_ref) ** 2)
    assert err_ada < err_rtn
