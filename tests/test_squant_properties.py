"""Property-based tests (hypothesis) on SQuant's discrete-domain invariants.

These are the paper's Eq. (9)-(12) constraints plus structural properties of
the flipping procedure, checked over randomized shapes / bit-widths / scales.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="optional dev dep: pip install -e .[dev]")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.squant import SQuantConfig, squant, squant_codes
from repro.quant.qtypes import pack_int4, unpack_int4, qmax_for_bits

TOL = 1e-3


@st.composite
def weight_case(draw):
    m = draw(st.integers(1, 12))
    ng = draw(st.integers(1, 6))
    g = draw(st.sampled_from([4, 8, 16, 32]))
    bits = draw(st.sampled_from([3, 4, 6, 8]))
    seed = draw(st.integers(0, 2**31 - 1))
    scale_mult = draw(st.sampled_from([0.5, 1.0, 2.0]))
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(m, ng * g)).astype(np.float32) * scale_mult
    return w, g, bits


@settings(max_examples=60, deadline=None)
@given(weight_case())
def test_invariants_random(case):
    w, g, bits = case
    m, n = w.shape
    qt, _ = squant(jnp.asarray(w), SQuantConfig(bits=bits, group_size=g))
    codes = np.asarray(qt.codes(), np.float64)
    d = codes - w / np.asarray(qt.scale)
    qmax = qmax_for_bits(bits)
    assert codes.max() <= qmax and codes.min() >= -qmax
    assert np.abs(d).max() < 1.0 + TOL                       # r_e relaxed
    assert np.abs(d.sum(1)).max() <= 0.5 + TOL               # r_c
    if g < n:
        assert np.abs(d.reshape(m, -1, g).sum(-1)).max() <= 1.0 + TOL  # r_k


@settings(max_examples=40, deadline=None)
@given(weight_case())
def test_flip_is_pm1_mutation(case):
    """Every SQuant output code differs from plain rounding by at most ±1,
    i.e. flips are single-step mutations (Sec. 3.3)."""
    w, g, bits = case
    scale = jnp.asarray(np.maximum(np.abs(w).max(1, keepdims=True), 1e-9)
                        / qmax_for_bits(bits))
    qmax = qmax_for_bits(bits)
    rounded = np.clip(np.round(w / np.asarray(scale)), -qmax, qmax)
    codes, _, _ = squant_codes(jnp.asarray(w), scale, bits=bits, group_size=g,
                               enable_k=True, enable_c=True)
    diff = np.abs(np.asarray(codes, np.float64) - rounded)
    assert diff.max() <= 1.0 + 1e-6
    # C stage flips at most one element per group beyond the K flips; total
    # mutated fraction is bounded by (0.5 per group + 1 per group) / g.
    assert (diff > 0).mean() <= (0.5 * g + 1.0) / g + 1e-6


@settings(max_examples=40, deadline=None)
@given(weight_case())
def test_determinism(case):
    w, g, bits = case
    cfg = SQuantConfig(bits=bits, group_size=g)
    a, _ = squant(jnp.asarray(w), cfg)
    b, _ = squant(jnp.asarray(w), cfg)
    np.testing.assert_array_equal(np.asarray(a.codes()), np.asarray(b.codes()))


@settings(max_examples=40, deadline=None)
@given(weight_case())
def test_scale_equivariance(case):
    """squant(c·W) with scale c·s gives identical codes (grid equivariance)."""
    w, g, bits = case
    scale = jnp.asarray(np.maximum(np.abs(w).max(1, keepdims=True), 1e-9)
                        / qmax_for_bits(bits))
    c1, _, _ = squant_codes(jnp.asarray(w), scale, bits=bits, group_size=g,
                            enable_k=True, enable_c=True)
    c2, _, _ = squant_codes(jnp.asarray(w * 4.0), scale * 4.0, bits=bits,
                            group_size=g, enable_k=True, enable_c=True)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 8), st.integers(1, 64))
def test_int4_pack_roundtrip(seed, m, half_n):
    rng = np.random.default_rng(seed)
    codes = rng.integers(-8, 8, size=(m, 2 * half_n)).astype(np.int8)
    packed = pack_int4(jnp.asarray(codes))
    assert packed.shape == (m, half_n)
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed)), codes)


@settings(max_examples=30, deadline=None)
@given(weight_case())
def test_dequantize_error_bound(case):
    """|dequant − w| ≤ scale per element (r_e ≤ 1.0 in real units), for
    non-clipped rows (max-scale never clips)."""
    w, g, bits = case
    qt, _ = squant(jnp.asarray(w), SQuantConfig(bits=bits, group_size=g))
    err = np.abs(np.asarray(qt.dequantize()) - w)
    bound = np.asarray(qt.scale) * (1.0 + TOL)
    assert np.all(err <= bound + 1e-6)
