"""Tests for the model-level on-the-fly quantization driver."""
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import quantize_tree
from repro.quant.qtypes import QuantizedTensor


def _tree(rng):
    return {
        "block0": {"attn": {"w": jnp.asarray(rng.normal(size=(64, 48)).astype(np.float32))},
                   "norm": {"gain": jnp.ones((64,), jnp.float32)}},
        "moe": {"w": jnp.asarray(rng.normal(size=(4, 32, 16)).astype(np.float32))},
        "conv": {"w_conv": jnp.asarray(rng.normal(size=(3, 3, 8, 16)).astype(np.float32))},
        "emb": {"table": jnp.asarray(rng.normal(size=(100, 64)).astype(np.float32))},
    }


def test_quantize_tree_structure(rng):
    tree, report = quantize_tree(_tree(rng), method="squant", bits=4,
                                 group_size=16)
    assert isinstance(tree["block0"]["attn"]["w"], QuantizedTensor)
    assert isinstance(tree["moe"]["w"], QuantizedTensor)
    assert isinstance(tree["conv"]["w_conv"], QuantizedTensor)
    # non-kernels untouched
    assert isinstance(tree["emb"]["table"], jnp.ndarray)
    assert isinstance(tree["block0"]["norm"]["gain"], jnp.ndarray)
    assert len(report.layers) == 3
    assert report.total_millis > 0
    # shapes preserved in the quantized container ((out,in)-major)
    assert tree["block0"]["attn"]["w"].shape == (48, 64)
    assert tree["moe"]["w"].shape == (4 * 16, 32)
    assert tree["conv"]["w_conv"].shape == (16, 8, 9)


def test_fake_quant_roundtrip_shapes(rng):
    src = _tree(rng)
    tree, _ = quantize_tree(src, method="squant", bits=8, group_size=16,
                            dequantize=True)
    import jax
    for a, b in zip(jax.tree_util.tree_leaves(src),
                    jax.tree_util.tree_leaves(tree)):
        assert a.shape == b.shape
    # 8-bit fake-quant is close to the original
    w0 = np.asarray(src["block0"]["attn"]["w"])
    w1 = np.asarray(tree["block0"]["attn"]["w"])
    assert np.abs(w0 - w1).max() < np.abs(w0).max() / 100


def test_methods_agree_at_high_bits(rng):
    src = _tree(rng)
    t_rtn, _ = quantize_tree(src, method="rtn", bits=8, dequantize=True)
    t_sq, _ = quantize_tree(src, method="squant", bits=8, group_size=16,
                            dequantize=True)
    w_r = np.asarray(t_rtn["block0"]["attn"]["w"])
    w_s = np.asarray(t_sq["block0"]["attn"]["w"])
    # SQuant flips move codes by at most one step from RTN
    scale = np.abs(np.asarray(src["block0"]["attn"]["w"])).max(0) / 127
    assert np.abs(w_r - w_s).max() <= scale.max() * (1 + 1e-5)


def test_int4_packing_in_tree(rng):
    tree, _ = quantize_tree(_tree(rng), method="squant", bits=4,
                            group_size=16)
    qt = tree["block0"]["attn"]["w"]
    assert qt.bits == 4
    assert qt.data.dtype == jnp.int8
    assert qt.data.shape[-1] == qt.shape[-1] // 2  # packed two-per-byte
    assert qt.nbytes() < 48 * 64  # strictly below one byte per weight
