"""CI-scale dry-run: the full launch path (mesh, shardings, lower, compile,
memory/cost/collective analysis) on an 8-device debug mesh in a subprocess.
The 256/512-chip production runs use the same code (see artifacts/dryrun)."""
import json
import os
import subprocess
import sys
import tempfile

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.mark.slow
@pytest.mark.parametrize("mesh", ["pod", "multipod"])
def test_dryrun_debug_mesh(mesh):
    with tempfile.TemporaryDirectory() as tmp:
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC
        env["REPRO_DRYRUN_DEVICES"] = "8"
        env["REPRO_DRYRUN_DEBUG_MESH"] = "1"
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "seamless-m4t-medium", "--shape", "decode_32k",
             "--mesh", mesh, "--out", tmp],
            capture_output=True, text=True, env=env, timeout=1200)
        assert out.returncode == 0, out.stderr[-3000:]
        art = os.path.join(
            tmp, f"seamless-m4t-medium__decode_32k__{mesh}.json")
        with open(art) as f:
            d = json.load(f)
        assert d["status"] == "ok", d
        assert d["hlo_flops"] > 0
        assert d["roofline"]["dominant"] in ("compute_s", "memory_s",
                                             "collective_s")
        assert d["collectives"]["total"] >= 0


@pytest.mark.slow
def test_dryrun_costing_debug():
    with tempfile.TemporaryDirectory() as tmp:
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC
        env["REPRO_DRYRUN_DEVICES"] = "8"
        env["REPRO_DRYRUN_DEBUG_MESH"] = "1"
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "seamless-m4t-medium", "--shape", "decode_32k",
             "--mesh", "pod", "--costing", "--out", tmp],
            capture_output=True, text=True, env=env, timeout=1200)
        assert out.returncode == 0, out.stderr[-3000:]
        art = os.path.join(
            tmp, "seamless-m4t-medium__decode_32k__pod__cost.json")
        with open(art) as f:
            d = json.load(f)
        assert d["status"] == "ok", d
        # extrapolated full depth, useful-flops ratio sane
        assert d["extrapolated_periods"] == 12
        ratio = d["roofline"]["model_flops_ratio"]
        # enc-dec decode recomputes cross-attention K/V per step, so the
        # useful-flops ratio is legitimately small; just sanity-bound it
        assert ratio is not None and 0.0001 < ratio <= 2.0, ratio
