"""Self-speculative decoding tests: the w4 quantization of a checkpoint
drafts for the w8 verifier on the paged continuous scheduler.

The tentpole contract is **bit-identity**: greedy acceptance emits exactly
the token stream verifier-only decode would produce — every emitted token
is either verified-argmax-equal to a draft or the verifier's own argmax at
the divergence row — so speculation may only change steps-per-token, never
tokens. The reference engine in every test is the same ``ServeConfig``
with ``speculative=False`` (whose own bit-identity against the solo
contiguous oracle is pinned in test_kvcache_paged.py).

Also covered here: the (target, draft) pair staged/swapped atomically by
the WeightStore, the per-request ``eos_id`` override and auto request ids
from :mod:`repro.serving.api`, Completion/SchedulerStats speculative
counters, and the declarative ServeConfig gate matrix (one parametrized
test per ``CONFIG_GATES`` row).
"""
import dataclasses
import re

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.serving import (Completion, Request, SchedulerStats, ServeConfig,
                           ServeEngine, StagedInfo)
from repro.serving.engine import CONFIG_GATES


def _tiny(seed=0, vocab=256, **over):
    cfg = get_config("granite-3-8b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32", n_layers=2, d_model=32,
                              n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
                              vocab=vocab, **over)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(seed))


def _paged(model, params, **over):
    base = dict(max_len=64, scheduler="continuous", max_slots=2,
                kv_backend="paged", block_size=4,
                quantize_weights="squant", weight_bits=8)
    base.update(over)
    return ServeEngine(model, params, ServeConfig(**base))


def _spec(model, params, **over):
    base = dict(speculative=True, draft_bits=4, draft_k=3)
    base.update(over)
    return _paged(model, params, **base)


def _reqs():
    """Mixed lengths, 4 requests on 2 slots: two admit mid-flight while
    residents are mid-decode (per-slot positions diverge immediately)."""
    return [Request(prompt=[1, 2, 3, 4, 5], max_new_tokens=12,
                    request_id=0),
            Request(prompt=[7, 8, 9, 10, 11, 12, 13, 14, 15],
                    max_new_tokens=7, request_id=1),
            Request(prompt=[3, 1, 4], max_new_tokens=15, request_id=2),
            Request(prompt=[9, 9, 8, 7, 6, 5, 4, 3, 2, 1, 2],
                    max_new_tokens=4, request_id=3)]


def _by_id(outs):
    return {c.request_id: c for c in outs}


def _assert_clean(eng):
    kv = eng.scheduler.kv
    kv.check_invariants()
    st = kv.stats()
    assert st["blocks_active"] == 0 and st["blocks_reserved"] == 0


# ---------------------------------------------------------------------------
# bit-identity (the tentpole win condition)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("draft_k,block_size", [(1, 4), (3, 4), (5, 8)])
def test_speculative_bit_identical_mixed_lengths(draft_k, block_size):
    model, params = _tiny()
    reqs = _reqs()
    ref = _by_id(_paged(model, params, block_size=block_size)
                 .generate(reqs))
    eng = _spec(model, params, draft_k=draft_k, block_size=block_size)
    outs = _by_id(eng.generate(reqs))
    for rid, c in outs.items():
        assert c.tokens == ref[rid].tokens
    st = eng.scheduler.stats()
    assert st["speculative"] and st["spec_cycles"] > 0
    assert 0 <= st["draft_tokens_accepted"] <= st["draft_tokens_proposed"]
    _assert_clean(eng)


def test_speculative_bit_identical_fp_target():
    """quantize_weights=None target: the drafter still quantizes (the
    ladder needs a cheaper tree below the verifier) and the emitted
    tokens still match fp verifier-only decode exactly."""
    model, params = _tiny()
    reqs = _reqs()
    ref = _by_id(_paged(model, params, quantize_weights=None)
                 .generate(reqs))
    eng = _spec(model, params, quantize_weights=None)
    outs = _by_id(eng.generate(reqs))
    for rid, c in outs.items():
        assert c.tokens == ref[rid].tokens
    _assert_clean(eng)


def test_speculative_bit_identical_with_chunked_admission():
    """prefill_chunk composes: chunked paged admissions run between
    speculative cycles of the resident slots."""
    model, params = _tiny()
    reqs = _reqs()
    ref = _by_id(_paged(model, params, prefill_chunk=3).generate(reqs))
    eng = _spec(model, params, prefill_chunk=3)
    outs = _by_id(eng.generate(reqs))
    for rid, c in outs.items():
        assert c.tokens == ref[rid].tokens
    assert eng.trace_counts["prefill_chunk"] > 0
    assert eng.trace_counts["verify"] > 0
    _assert_clean(eng)


def test_speculative_eos_retirement_bit_identical():
    """Global EOS and a per-request ``eos_id`` override both retire at
    the same token speculation or not — including when the EOS lands
    mid-accepted-run (the emission loop checks per token, never emits
    past it)."""
    model, params = _tiny()
    reqs = _reqs()
    base = _by_id(_paged(model, params).generate(reqs))
    long = base[2].tokens
    eos = next(t for t in long[:8] if t not in base[0].tokens)

    # global EOS via ServeConfig
    ref = _by_id(_paged(model, params, eos_id=eos).generate(reqs))
    outs = _by_id(_spec(model, params, eos_id=eos).generate(reqs))
    for rid in ref:
        assert outs[rid].tokens == ref[rid].tokens
    assert ref[2].tokens == long[:long.index(eos) + 1]

    # per-request override (config eos stays -1: never stop)
    reqs_o = _reqs()
    reqs_o[2] = dataclasses.replace(reqs_o[2], eos_id=eos)
    ref_o = _by_id(_paged(model, params).generate(reqs_o))
    eng = _spec(model, params)
    outs_o = _by_id(eng.generate(reqs_o))
    for rid in ref_o:
        assert outs_o[rid].tokens == ref_o[rid].tokens
    assert outs_o[2].tokens == long[:long.index(eos) + 1]
    assert len(outs_o[0].tokens) == 12      # others unaffected
    _assert_clean(eng)


# ---------------------------------------------------------------------------
# counters / stats plumbing
# ---------------------------------------------------------------------------

def test_completion_and_stats_speculative_counters():
    model, params = _tiny()
    reqs = _reqs()
    eng = _spec(model, params)
    outs = eng.generate(reqs)
    st = eng.scheduler.stats()
    assert isinstance(st, SchedulerStats)
    for c in outs:
        assert 1 <= c.steps <= len(c.tokens)
        assert 0 <= c.draft_tokens_accepted <= c.draft_tokens_proposed
    # a draft_k=3 run over 38 budgeted tokens must accept something
    assert sum(c.draft_tokens_accepted for c in outs) > 0
    # some completion finished in fewer engine steps than tokens emitted
    assert any(c.steps < len(c.tokens) for c in outs)
    # scheduler totals == the per-completion sums
    assert st["draft_tokens_accepted"] == \
        sum(c.draft_tokens_accepted for c in outs)
    assert st["draft_tokens_proposed"] == \
        sum(c.draft_tokens_proposed for c in outs)
    assert st["acceptance_rate"] == pytest.approx(
        st["draft_tokens_accepted"] / st["draft_tokens_proposed"])
    assert set(st["accepted_len"]) == {"p50", "p95"}
    assert 1.0 <= st["accepted_len"]["p50"] <= st["accepted_len"]["p95"]

    # non-speculative engines report inert speculative fields
    ref_eng = _paged(model, params)
    ref = ref_eng.generate(reqs)
    rst = ref_eng.scheduler.stats()
    assert not rst["speculative"] and rst["spec_cycles"] == 0
    assert rst["acceptance_rate"] == 0.0
    for c in ref:
        assert c.steps == len(c.tokens)
        assert c.draft_tokens_proposed == c.draft_tokens_accepted == 0


def test_trace_counts_draft_and_verify_jits():
    """One verify trace per k_eff width, one chain trace per k_eff, one
    draft prefill/admit pair — and the non-speculative baseline keeps its
    exact trace dict (no speculative keys leak in)."""
    model, params = _tiny()
    eng = _spec(model, params)
    eng.generate(_reqs())
    tc = eng.trace_counts
    assert tc["verify"] >= 1
    assert tc["draft_chain"] >= 1
    assert tc["draft_prefill"] >= 1 and tc["draft_admit"] >= 1

    ref = _paged(model, params)
    ref.generate(_reqs())
    assert "verify" not in ref.trace_counts
    assert "draft_chain" not in ref.trace_counts


# ---------------------------------------------------------------------------
# (target, draft) weight pair
# ---------------------------------------------------------------------------

def test_weight_store_stages_target_draft_pair():
    model, params = _tiny()
    eng = _spec(model, params)
    v1 = eng.store.current
    assert v1.draft_params is not None

    def leaf(tree):
        return np.asarray(jax.tree_util.tree_leaves(tree)[0])

    # stage a different checkpoint: BOTH trees of the pair move together
    _, params2 = _tiny(seed=1)
    eng.store.stage(params2, source="test", block=True)
    info = eng.store.staged_info()
    assert isinstance(info, StagedInfo) and info.version == 2
    assert info["version"] == 2 and info.age_ms >= 0.0
    outs = eng.generate(_reqs())
    assert all(c.weights_version == 2 for c in outs)
    v2 = eng.store.current
    assert v2.version == 2 and v2.draft_params is not None
    assert not np.array_equal(leaf(v2.draft_params), leaf(v1.draft_params))

    # tokens from the swapped pair match a fresh engine seeded on params2
    ref = _by_id(_paged(model, params2).generate(_reqs()))
    for c in outs:
        assert c.tokens == ref[c.request_id].tokens


def test_weight_store_rejects_draft_without_fp_source():
    """A quantized-native serving tree cannot rebuild the drafter: the
    stage must fail (into ``errors`` on the background path) and serving
    must continue on the previous pair."""
    from repro.serving.weights import WeightStore, make_draft_quantize_fn

    model, params = _tiny()
    cfg = ServeConfig(max_len=64, scheduler="continuous",
                      kv_backend="paged", block_size=4, speculative=True)
    draft_fn = make_draft_quantize_fn(model, cfg)
    store = WeightStore(lambda t: (t, None), params,
                        draft_quantize_fn=draft_fn)
    assert store.current.draft_params is not None
    with pytest.raises(ValueError, match="fp"):
        store.stage(serving_params=params, source="ckpt", block=True)
    assert store.version == 1


# ---------------------------------------------------------------------------
# serving API surface (repro.serving.api)
# ---------------------------------------------------------------------------

def test_request_auto_ids_and_aliases():
    r1, r2 = Request(prompt=[1, 2]), Request(prompt=[3])
    assert isinstance(r1.request_id, int) and r1.request_id != r2.request_id
    assert Request(prompt=[1], request_id=7).request_id == 7
    assert r1.eos_id is None

    # deprecated aliases point at the one definition
    from repro.serving import api, engine, scheduler
    assert scheduler.Request is api.Request is engine.Request
    assert scheduler.Completion is api.Completion is Completion

    # dict-style access shim on the typed stats records
    info = StagedInfo(version=3, age_ms=1.5)
    assert info["version"] == 3 and info.get("missing", 0) == 0
    assert info.to_dict() == {"version": 3, "age_ms": 1.5}
    with pytest.raises(KeyError):
        info["nope"]
    st = SchedulerStats(kind="round", steps=4)
    assert st["steps"] == 4 and st.to_dict()["kind"] == "round"
    # Completion stays a plain dataclass with the speculative counters
    c = Completion(request_id=1, tokens=[4, 5], prefill_ms=1.0,
                   decode_ms=2.0)
    assert c.steps == 0 and c.draft_tokens_proposed == 0


# ---------------------------------------------------------------------------
# config gate matrix
# ---------------------------------------------------------------------------

_PAGED = dict(scheduler="continuous", kv_backend="paged")
_GATE_CASES = [
    ("prefill_chunk_range", dict(prefill_chunk=-1),
     ValueError, "prefill_chunk must be >= 0"),
    ("kv_backend_enum", dict(kv_backend="mmap"),
     ValueError, "unknown kv_backend"),
    ("block_size_range", dict(block_size=0, **_PAGED),
     ValueError, "block_size must be >= 1"),
    ("block_size_divides", dict(block_size=5, **_PAGED),
     ValueError, "must divide max_len"),
    ("kv_blocks_range", dict(kv_blocks=-1, **_PAGED),
     ValueError, "kv_blocks must be >= 0"),
    ("draft_k_range", dict(speculative=True, draft_k=0, **_PAGED),
     ValueError, "draft_k must be >= 1"),
    ("draft_bits_range", dict(speculative=True, draft_bits=1, **_PAGED),
     ValueError, "must be in [2, 8]"),
    ("paged_x_round", dict(kv_backend="paged"),
     NotImplementedError, "unsupported combination: kv_backend='paged'"),
    ("speculative_x_contiguous", dict(speculative=True,
                                      scheduler="continuous"),
     NotImplementedError, "unsupported combination: speculative decoding"),
    ("speculative_x_quant_kv", dict(speculative=True, quantize_kv=True,
                                    **_PAGED),
     NotImplementedError, "unsupported combination: speculative x quantize"),
    ("speculative_x_sampling", dict(speculative=True, temperature=0.7,
                                    **_PAGED),
     NotImplementedError, "unsupported combination: speculative x sampling"),
]


@pytest.mark.parametrize("name,over,err,msg", _GATE_CASES,
                         ids=[c[0] for c in _GATE_CASES])
def test_config_gate_matrix(name, over, err, msg):
    with pytest.raises(err, match=re.escape(msg)):
        ServeConfig(max_len=64, **over)


def test_gate_matrix_covers_every_row():
    """Adding a CONFIG_GATES row without a matrix case fails here; every
    feature-pair row must carry the uniform prefix."""
    assert {c[0] for c in _GATE_CASES} == {g.name for g in CONFIG_GATES}
    for g in CONFIG_GATES:
        if "_x_" in g.name:
            assert isinstance(g.message, str)
            assert g.message.startswith("unsupported combination: ")


def test_valid_speculative_config_passes_gates():
    cfg = ServeConfig(max_len=64, speculative=True, **_PAGED)
    assert cfg.draft_bits == 4 and cfg.draft_k == 4
