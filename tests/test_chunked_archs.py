"""Per-architecture chunked-prefill agreement: the PR-10 gate lift.

Every decoder-only architecture in the registry now runs ``prefill_chunk
> 0`` on the continuous scheduler. Plain-attention dense stacks stay
bit-identical (covered by the existing scheduler tests); the stacks swept
here — sliding-window rings, MLA latent caches, MoE capacity routing,
mamba/rwkv recurrent state — are tolerance-equivalent instead, each held
to its measured ``AGREEMENT_BUDGETS`` floor via teacher-forced greedy
agreement against a monolithic-prefill oracle (methodology in
``docs/equivalence.md``). The sweep covers chunk widths 1 (slowest
catch-up), a non-dividing width, a width at least the prompt length
(single-chunk admission), and a mid-flight admission into a freed slot.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.serving.engine import Request, ServeConfig, ServeEngine
from repro.serving.equivalence import (AGREEMENT_BUDGETS, active_budget_keys,
                                       agreement_budget,
                                       greedy_token_agreement, oracle_tokens)

# label -> (registry arch, shrink overrides). Mirrors the
# ``CHUNKED_ARCH_ROWS`` ladder in benchmarks/bench_serving.py: the jamba
# row isolates the mamba mixer; the mixtral row is the composed
# sliding_window x moe stack.
ARCHS = {
    "sliding_window": ("granite-3-8b", dict(n_layers=2, window=8)),
    "mla": ("minicpm3-4b", dict(n_layers=2)),
    "moe": ("moonshot-v1-16b-a3b", dict(n_layers=2)),
    "mamba": ("jamba-1.5-large-398b",
              dict(n_layers=2, block_pattern=("m", "a"), moe=None)),
    "rwkv": ("rwkv6-1.6b", dict(n_layers=2)),
    "sliding_window+moe": ("mixtral-8x7b", dict(n_layers=2, window=8)),
}

_MODELS = {}


def _model(label):
    if label not in _MODELS:
        name, over = ARCHS[label]
        cfg = dataclasses.replace(get_config(name, reduced=True),
                                  dtype="float32", **over)
        m = build_model(cfg)
        _MODELS[label] = (m, m.init(jax.random.PRNGKey(0)))
    return _MODELS[label]


def _wave():
    rng = np.random.default_rng(3)
    reqs = []
    for i in range(4):
        plen = int(rng.integers(5, 10))
        prompt = [int(t) for t in rng.integers(1, 200, size=plen)]
        reqs.append(Request(prompt=prompt, max_new_tokens=8, request_id=i))
    return reqs


@pytest.mark.parametrize("label", sorted(ARCHS))
def test_chunk_split_sweep_within_budget(label):
    """chunk in {1, non-dividing, >= prompt}: a fresh admission wave's
    teacher-forced agreement vs the monolithic oracle stays at or above
    the architecture's composed budget; budget 1.0 means every compared
    token matched (exact identity)."""
    model, params = _model(label)
    reqs = _wave()
    base = ServeConfig(max_batch=4, max_len=48, scheduler="continuous")
    oracle_eng = ServeEngine(model, params, base)
    oracle = oracle_tokens(oracle_eng.generate(reqs))
    oracle_eng.close()
    for chunk in (1, 3, 16):
        cfg = dataclasses.replace(base, prefill_chunk=chunk)
        budget = agreement_budget(cfg, model.cfg)
        eng = ServeEngine(model, params, cfg)
        rep = greedy_token_agreement(eng, reqs, oracle)
        eng.close()
        assert rep.compared == sum(r.max_new_tokens for r in reqs)
        rep.assert_budget(budget, f"{label} chunk={chunk}")


@pytest.mark.parametrize("label", sorted(ARCHS))
def test_midflight_chunked_admission_within_budget(label):
    """A chunked admission into a freed slot commits to clock P and
    left-pads to P; its tokens agree with the round engine run at the
    same padding (filler-pinned) within the architecture's budget —
    the mid-flight leg of the sweep."""
    model, params = _model(label)
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=2, request_id=0),
            Request(prompt=[5, 6, 7, 8, 9], max_new_tokens=12,
                    request_id=1),
            Request(prompt=[11, 12, 13], max_new_tokens=8, request_id=2)]
    ccfg = ServeConfig(max_batch=2, max_len=64, scheduler="continuous",
                       prefill_chunk=2)
    cont = ServeEngine(model, params, ccfg)
    cont.generate(reqs)     # discover request 2's admission clock
    adm = {e["request_id"]: e for e in cont.scheduler.admission_log}
    clock = adm[2]["clock"]
    assert adm[2]["chunks"] > 1          # genuinely multi-chunk admission
    rnd = ServeEngine(model, params, ServeConfig(max_batch=2, max_len=64))
    ctrl = rnd.generate(
        [Request(prompt=reqs[2].prompt, max_new_tokens=8, request_id=2),
         Request(prompt=[3] * clock, max_new_tokens=1, request_id=99)])
    rnd.close()
    # teacher-force only the late request against its equal-padding oracle
    rep = greedy_token_agreement(cont, reqs, {2: list(ctrl[0].tokens)})
    cont.close()
    assert rep.compared == 8
    rep.assert_budget(agreement_budget(ccfg, model.cfg),
                      f"{label} mid-flight")


def test_mla_chunked_identity():
    """MLA's budget is 1.0 (whole-cache latent re-expansion reproduced
    the monolithic expansion exactly at serving widths) — so its chunked
    tokens owe full identity, not just a rate."""
    model, params = _model("mla")
    reqs = _wave()
    base = ServeConfig(max_batch=4, max_len=48, scheduler="continuous")
    assert agreement_budget(
        dataclasses.replace(base, prefill_chunk=3), model.cfg) == 1.0
    oracle_eng = ServeEngine(model, params, base)
    mono = {c.request_id: c.tokens for c in oracle_eng.generate(reqs)}
    oracle_eng.close()
    eng = ServeEngine(model, params,
                      dataclasses.replace(base, prefill_chunk=3))
    chunked = {c.request_id: c.tokens for c in eng.generate(reqs)}
    eng.close()
    assert chunked == mono


@pytest.mark.parametrize("label",
                         ["sliding_window", "mla", "mamba", "rwkv"])
def test_paged_backend_still_gated_for_non_positional_caches(label):
    """The paged backend requires per-position cache rows; rings, latent
    caches, and recurrent state stay gated (engine.ARCH_GATES) with a
    pointer to the contiguous backend."""
    model, params = _model(label)
    with pytest.raises(NotImplementedError, match="paged KV cache"):
        ServeEngine(model, params,
                    ServeConfig(max_batch=2, max_len=32,
                                scheduler="continuous",
                                kv_backend="paged", block_size=8))


def test_agreement_budget_composes_multiplicatively():
    """The regression the satellite pins: ``agreement_budget`` used to be
    a binary int8_kv-or-exact lookup, silently handing stacked features
    the wrong floor. It now multiplies every active key's floor."""
    mixtral = dataclasses.replace(get_config("mixtral-8x7b", reduced=True),
                                  dtype="float32", n_layers=2, window=8)
    chunked_quant = ServeConfig(max_batch=2, max_len=32,
                                scheduler="continuous", prefill_chunk=4,
                                quantize_kv=True)
    assert active_budget_keys(chunked_quant, mixtral) == \
        ["int8_kv", "sliding_window", "moe"]
    expect = (AGREEMENT_BUDGETS["int8_kv"]
              * AGREEMENT_BUDGETS["sliding_window"]
              * AGREEMENT_BUDGETS["moe"])
    assert agreement_budget(chunked_quant, mixtral) \
        == pytest.approx(expect)
    assert agreement_budget(chunked_quant, mixtral) \
        == pytest.approx(0.79135)     # pinned: 0.98 * 0.95 * 0.85
    # arch keys only activate under chunk-continuation prefill
    mono = dataclasses.replace(chunked_quant, prefill_chunk=0)
    assert agreement_budget(mono, mixtral) == AGREEMENT_BUDGETS["int8_kv"]
    # ... which includes the paged backend's suffix continuations
    dense = dataclasses.replace(get_config("granite-3-8b", reduced=True),
                                dtype="float32", n_layers=2)
    moonshot = dataclasses.replace(
        get_config("moonshot-v1-16b-a3b", reduced=True),
        dtype="float32", n_layers=2)
    paged = ServeConfig(max_batch=2, max_len=32, scheduler="continuous",
                        kv_backend="paged", block_size=8)
    assert agreement_budget(paged, moonshot) == AGREEMENT_BUDGETS["moe"]
    assert agreement_budget(paged, dense) == 1.0
    # legacy single-argument form (serve-config keys only) still works
    assert agreement_budget(chunked_quant) == AGREEMENT_BUDGETS["int8_kv"]
    assert agreement_budget(mono) == AGREEMENT_BUDGETS["int8_kv"]
    assert agreement_budget(ServeConfig(max_batch=2, max_len=32)) == 1.0
