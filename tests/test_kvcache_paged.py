"""Paged KV-cache tests: bit-identity with the contiguous oracle on mixed
lengths, EOS retirement + block reclamation (free-pool accounting, no
leaks), copy-on-write after a shared prefix, pool-exhaustion admission
backpressure, gather-attention kernel parity, and config validation.

The bit-identity contract: because ``block_size`` divides ``max_len``, the
paged gather width equals the contiguous cache width, so a paged slot's
decode runs the exact same einsums as a solo round-engine run of the same
request (positions ``0..L-1``, no left-padding) — masked-out columns
contribute exact zeros through the finite-NEG_INF softmax.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.serving.engine import Request, ServeConfig, ServeEngine


def _tiny(seed=0, vocab=256, **over):
    cfg = get_config("granite-3-8b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32", n_layers=2, d_model=32,
                              n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
                              vocab=vocab, **over)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(seed))


def _paged(model, params, **over):
    base = dict(max_len=32, scheduler="continuous", max_slots=2,
                kv_backend="paged", block_size=4)
    base.update(over)
    return ServeEngine(model, params, ServeConfig(**base))


def _solo_oracle(model, params, reqs, max_len=32):
    """Per-request solo round-engine runs: the bit-exactness reference at
    equal effective context (prompt at positions 0..L-1)."""
    out = {}
    for r in reqs:
        eng = ServeEngine(model, params,
                          ServeConfig(max_batch=1, max_len=max_len))
        out[r.request_id] = eng.generate([r])[0].tokens
    return out


def _kv_stats(eng):
    return eng.scheduler.stats()["kv"]


def _assert_no_leaks(kv):
    """Every non-trash block is free, cached, or active; nothing active
    and nothing reserved after all requests completed."""
    assert kv["blocks_active"] == 0
    assert kv["blocks_reserved"] == 0
    assert kv["blocks_free"] + kv["blocks_cached"] == kv["blocks_total"] - 1


# ---------------------------------------------------------------------------
# bit-identity
# ---------------------------------------------------------------------------

def test_paged_tokens_bit_identical_to_contiguous_oracle_mixed_lengths():
    model, params = _tiny()
    reqs = [Request(prompt=[1, 2, 3, 4, 5, 6], max_new_tokens=8,
                    request_id=0),
            Request(prompt=[7, 8], max_new_tokens=3, request_id=1),
            Request(prompt=[9, 10, 11], max_new_tokens=5, request_id=2),
            Request(prompt=[4] * 11, max_new_tokens=6, request_id=3)]
    oracle = _solo_oracle(model, params, reqs)
    eng = _paged(model, params)
    outs = eng.generate(reqs)
    for c in outs:
        assert c.tokens == oracle[c.request_id]
    sch = eng.scheduler.stats()
    assert sch["admitted"] == 4 and sch["retired"] == 4
    _assert_no_leaks(_kv_stats(eng))


def test_paged_outlives_contiguous_admission_horizon():
    """The contiguous backend can only admit while clock + max_new fits
    max_len (wave resets); paged slots each use their own positions, so a
    full-budget request admits mid-flight with no horizon wait — and
    tokens still match the solo oracle."""
    model, params = _tiny()
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=12, request_id=0),
            Request(prompt=[5, 6, 7, 8], max_new_tokens=12, request_id=1),
            Request(prompt=[9, 10], max_new_tokens=14, request_id=2)]
    oracle = _solo_oracle(model, params, reqs, max_len=16)
    eng = _paged(model, params, max_len=16, block_size=4, max_slots=2)
    outs = eng.generate(reqs)
    for c in outs:
        assert c.tokens == oracle[c.request_id]
    _assert_no_leaks(_kv_stats(eng))


# ---------------------------------------------------------------------------
# retirement + reclamation
# ---------------------------------------------------------------------------

def test_eos_retirement_reclaims_blocks_no_leak_across_waves():
    model, params = _tiny()
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=10, request_id=0),
            Request(prompt=[5, 6, 7], max_new_tokens=10, request_id=1),
            Request(prompt=[11, 12], max_new_tokens=4, request_id=2)]
    base = _paged(model, params).generate(reqs)
    eos = next(t for t in base[0].tokens[:6]
               if t not in base[1].tokens and t != 0)
    cut = base[0].tokens.index(eos) + 1

    eng = _paged(model, params, eos_id=eos)
    outs = eng.generate(reqs)
    assert outs[0].tokens == base[0].tokens[:cut]
    assert len(outs[1].tokens) == 10
    kv = _kv_stats(eng)
    _assert_no_leaks(kv)

    # repeated waves over the same engine must not leak blocks: the pool
    # accounting returns to empty-active after every generate()
    for _ in range(3):
        eng.generate(reqs)
        _assert_no_leaks(_kv_stats(eng))


# ---------------------------------------------------------------------------
# shared-prefix reuse + copy-on-write
# ---------------------------------------------------------------------------

def test_shared_prefix_cow_divergence_bit_identical():
    """Two requests sharing a long prompt prefix: the second's admission
    reuses the first's blocks (prefix hit), COWs at the divergence point,
    and both streams stay bit-identical to their solo oracles."""
    model, params = _tiny()
    sys_prompt = [3, 1, 4, 1, 5, 9, 2, 6]            # two full blocks @ bs=4
    reqs = [Request(prompt=sys_prompt + [10, 11], max_new_tokens=6,
                    request_id=0),
            Request(prompt=sys_prompt + [12, 13, 14], max_new_tokens=6,
                    request_id=1),
            Request(prompt=list(sys_prompt), max_new_tokens=6,
                    request_id=2)]
    oracle = _solo_oracle(model, params, reqs)
    eng = _paged(model, params, max_slots=2)
    outs = eng.generate(reqs)
    for c in outs:
        assert c.tokens == oracle[c.request_id]
    kv = _kv_stats(eng)
    assert kv["prefix_hits"] >= 1
    assert kv["prefix_tokens_reused"] >= len(sys_prompt)
    assert kv["cow_copies"] >= 1                      # divergent tail write
    _assert_no_leaks(kv)


def test_identical_prompts_share_full_blocks():
    """An identical repeated prompt shares every full block; only the tail
    re-prefills. Sequential (slot-reuse) and concurrent sharing both stay
    bit-identical."""
    model, params = _tiny()
    prompt = [7, 7, 2, 9, 4, 4, 8, 1, 6]
    reqs = [Request(prompt=list(prompt), max_new_tokens=5, request_id=i)
            for i in range(4)]
    oracle = _solo_oracle(model, params, reqs)
    eng = _paged(model, params, max_slots=2)
    outs = eng.generate(reqs)
    for c in outs:
        assert c.tokens == oracle[c.request_id]
    kv = _kv_stats(eng)
    assert kv["prefix_hits"] >= 3                     # all but the first
    assert kv["prefix_tokens_reused"] >= 3 * (len(prompt) // 4) * 4
    _assert_no_leaks(kv)


# ---------------------------------------------------------------------------
# pool exhaustion → admission backpressure
# ---------------------------------------------------------------------------

def test_pool_exhaustion_backpressures_admission_and_completes():
    """A pool that fits one request's block budget at a time: the second
    request waits for the first to retire (backpressure, not failure) and
    both complete with oracle-identical tokens."""
    model, params = _tiny()
    reqs = [Request(prompt=[1, 2, 3, 4, 5], max_new_tokens=7, request_id=0),
            Request(prompt=[6, 7, 8, 9], max_new_tokens=8, request_id=1)]
    # each request needs ceil((L+m)/4) = 3 blocks; 4 allocatable blocks
    # fit one in flight (plus cached-prefix eviction headroom) but not two
    oracle = _solo_oracle(model, params, reqs)
    eng = _paged(model, params, max_slots=2, kv_blocks=5)
    outs = eng.generate(reqs)
    for c in outs:
        assert c.tokens == oracle[c.request_id]
    adm = {e["request_id"]: i
           for i, e in enumerate(eng.scheduler.admission_log)}
    assert adm[1] > adm[0]                            # serialized admission
    assert eng.scheduler.stats()["max_occupancy"] == 1
    _assert_no_leaks(_kv_stats(eng))


def test_oversized_request_rejected_against_pool():
    model, params = _tiny()
    eng = _paged(model, params, kv_blocks=3)
    with pytest.raises(ValueError, match="KV blocks"):
        eng.generate([Request(prompt=[1, 2, 3, 4, 5, 6, 7, 8],
                              max_new_tokens=8, request_id=0)])


# ---------------------------------------------------------------------------
# gather-attention kernel parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rep", [1, 4])
def test_paged_attention_kernel_matches_ref(rep):
    from repro.kernels.paged_attention import (paged_attention,
                                               paged_attention_ref)
    key = jax.random.PRNGKey(0)
    b, kv, d, bs, nb_slot, nblocks = 3, 2, 16, 4, 6, 20
    h = kv * rep
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    k_pool = jax.random.normal(ks[1], (nblocks, bs, kv, d), jnp.float32)
    v_pool = jax.random.normal(ks[2], (nblocks, bs, kv, d), jnp.float32)
    bt = jax.random.randint(ks[3], (b, nb_slot), 1, nblocks).astype(jnp.int32)
    lengths = jnp.asarray([0, 7, 21], jnp.int32)      # mixed fills
    scale = 1.0 / np.sqrt(d)
    ref = paged_attention_ref(q, k_pool, v_pool, bt, lengths, scale=scale)
    ker = paged_attention(q, k_pool, v_pool, bt, lengths, scale=scale,
                          use_pallas="interpret")
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_ref_masks_trash_columns():
    """Columns past a row's length must contribute exactly zero: poisoning
    masked pool blocks with huge values cannot change the output."""
    from repro.kernels.paged_attention import paged_attention_ref
    key = jax.random.PRNGKey(1)
    b, h, kv, d, bs, nb_slot, nblocks = 2, 2, 2, 8, 4, 4, 9
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    k_pool = jax.random.normal(ks[1], (nblocks, bs, kv, d), jnp.float32)
    v_pool = jax.random.normal(ks[2], (nblocks, bs, kv, d), jnp.float32)
    bt = jnp.arange(1, 1 + b * nb_slot, dtype=jnp.int32).reshape(b, nb_slot)
    lengths = jnp.asarray([5, 2], jnp.int32)
    base = paged_attention_ref(q, k_pool, v_pool, bt, lengths, scale=0.35)
    mask = np.zeros((nblocks, bs, 1, 1), np.float32)
    for row in range(b):
        L = int(lengths[row])
        for j in range(nb_slot):
            for o in range(bs):
                if j * bs + o > L:
                    mask[int(bt[row, j]), o] = 1.0
    poisoned_k = k_pool + 1e6 * jnp.asarray(mask)
    poisoned_v = v_pool + 1e6 * jnp.asarray(mask)
    out = paged_attention_ref(q, poisoned_k, poisoned_v, bt, lengths,
                              scale=0.35)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))


# ---------------------------------------------------------------------------
# quantized KV (int8 pool + per-(position, head) scales)
# ---------------------------------------------------------------------------

def _pool_layer0(cache):
    """First layer's pool leaves for either cache layout."""
    if "list" in cache:
        return cache["list"][0]["b0"]
    return jax.tree_util.tree_map(lambda x: x[0], cache["periods"])["b0"]


def test_quantized_pool_dtype_and_scale_shapes():
    """The _ensure_pool regression: the paged pool must honor
    cfg.quantize_kv — int8 K/V code pools plus (num_blocks, block_size,
    KV) fp32 scale pools — not silently allocate fp (the bug this pins:
    _ensure_pool hardcoded quantize_kv=False)."""
    model, params = _tiny()
    eng = _paged(model, params, quantize_kv=True)
    eng.generate([Request(prompt=[1, 2, 3, 4, 5], max_new_tokens=4,
                          request_id=0)])
    layer = _pool_layer0(eng.scheduler.kv._cache)
    nblocks, bs = eng.scheduler.kv.num_blocks, eng.scheduler.kv.block_size
    assert layer["k"].dtype == jnp.int8 and layer["v"].dtype == jnp.int8
    for name in ("k_scale", "v_scale"):
        assert layer[name].shape == (nblocks, bs, 1)   # n_kv_heads=1
        assert layer[name].dtype == jnp.float32
    st = _kv_stats(eng)
    assert st["quantize_kv"] is True
    # int8 codes + fp32 scale must beat the fp32 pool on bytes/position
    fp = _paged(model, params)
    fp.generate([Request(prompt=[1, 2, 3], max_new_tokens=2, request_id=0)])
    assert st["bytes_per_position"] < _kv_stats(fp)["bytes_per_position"]
    _assert_no_leaks(st)


def test_quantized_registry_cow_eviction_invariants():
    """Registry / COW / eviction bookkeeping must hold unchanged when every
    block move carries codes + scales: shared-prefix hits, COW at the
    divergence point, backpressure under a tight pool, chunked admission —
    all with check_invariants() and leak-free retirement."""
    model, params = _tiny()
    sys_prompt = [3, 1, 4, 1, 5, 9, 2, 6]
    reqs = [Request(prompt=sys_prompt + [10, 11], max_new_tokens=6,
                    request_id=0),
            Request(prompt=sys_prompt + [12, 13, 14], max_new_tokens=6,
                    request_id=1),
            Request(prompt=list(sys_prompt), max_new_tokens=6,
                    request_id=2)]
    eng = _paged(model, params, quantize_kv=True, max_slots=2)
    outs = eng.generate(reqs)
    assert all(len(c.tokens) == 6 for c in outs)
    kv = _kv_stats(eng)
    assert kv["prefix_hits"] >= 1
    assert kv["prefix_tokens_reused"] >= len(sys_prompt)
    assert kv["cow_copies"] >= 1
    eng.scheduler.kv.check_invariants()
    _assert_no_leaks(kv)

    # tight pool: admission backpressure + cached-block eviction still
    # account correctly when blocks are (codes, scales) pairs
    tight = [Request(prompt=[1, 2, 3, 4, 5], max_new_tokens=7,
                     request_id=0),
             Request(prompt=[6, 7, 8, 9], max_new_tokens=8, request_id=1)]
    eng2 = _paged(model, params, quantize_kv=True, max_slots=2, kv_blocks=5)
    outs2 = eng2.generate(tight)
    assert [len(c.tokens) for c in outs2] == [7, 8]
    assert eng2.scheduler.stats()["max_occupancy"] == 1
    eng2.scheduler.kv.check_invariants()
    _assert_no_leaks(_kv_stats(eng2))

    # chunked admission under quantize_kv (the second lifted gate):
    # per-slot block-scatter completion must carry scales too
    eng3 = _paged(model, params, quantize_kv=True, prefill_chunk=4)
    outs3 = eng3.generate(reqs)
    assert all(len(c.tokens) == 6 for c in outs3)
    assert eng3.trace_counts["prefill_chunk"] > 0
    eng3.scheduler.kv.check_invariants()
    _assert_no_leaks(_kv_stats(eng3))


def test_quantized_agreement_vs_fp_paged_oracle():
    """Tolerance-equivalence slice at test scale: int8-KV greedy tokens vs
    the fp paged oracle under teacher forcing. At this tiny width
    (d_model=32) the measured agreement is ~0.97 — below the 0.98
    production budget enforced on the bench workload's realistic widths —
    so the test floor is 0.85; the fp engine must self-agree exactly."""
    from repro.serving.equivalence import (greedy_token_agreement,
                                           oracle_tokens)
    model, params = _tiny()
    reqs = [Request(prompt=[1, 2, 3, 4, 5, 6], max_new_tokens=8,
                    request_id=0),
            Request(prompt=[7, 8, 9], max_new_tokens=8, request_id=1),
            Request(prompt=[11, 12, 13, 14], max_new_tokens=8,
                    request_id=2),
            Request(prompt=[4] * 9, max_new_tokens=8, request_id=3)]
    oracle = oracle_tokens(_paged(model, params).generate(reqs))

    fp_rep = greedy_token_agreement(_paged(model, params), reqs, oracle)
    assert fp_rep.rate == 1.0 and fp_rep.compared == 32

    q_rep = greedy_token_agreement(
        _paged(model, params, quantize_kv=True), reqs, oracle)
    assert q_rep.compared == 32
    q_rep.assert_budget(0.85, label="tiny-width int8 KV")


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

def test_config_validation():
    with pytest.raises(ValueError, match="divide max_len"):
        ServeConfig(max_len=30, scheduler="continuous",
                    kv_backend="paged", block_size=4)
    with pytest.raises(NotImplementedError, match="scheduler='continuous'"):
        ServeConfig(scheduler="round", kv_backend="paged")
    # paged × chunked admission is supported now (PR 7) — constructs fine
    cfg = ServeConfig(scheduler="continuous", kv_backend="paged",
                      prefill_chunk=8)
    assert cfg.prefill_chunk == 8 and cfg.kv_backend == "paged"
    with pytest.raises(ValueError, match="kv_backend"):
        ServeConfig(kv_backend="banana")
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServeConfig(prefill_chunk=-1)
    # quantized KV composes with the paged backend AND chunked admission
    # now (the PR-8 gate lift) — both previously raised NotImplementedError
    cfg = ServeConfig(scheduler="continuous", kv_backend="paged",
                      quantize_kv=True)
    assert cfg.quantize_kv and cfg.kv_backend == "paged"
    cfg = ServeConfig(scheduler="continuous", kv_backend="paged",
                      prefill_chunk=4, quantize_kv=True)
    assert cfg.quantize_kv and cfg.prefill_chunk == 4


def test_contiguous_trace_counts_unchanged_by_kvcache_api():
    """The API move must not add paged counters to contiguous engines or
    change their trace behavior (exact-dict assert, mirroring
    test_scheduler's)."""
    model, params = _tiny()
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_len=32))
    eng.generate([Request(prompt=[1, 2, 3], max_new_tokens=4)])
    assert eng.trace_counts == {"prefill": 1, "prefill_chunk": 0,
                                "decode": 1, "admit": 0}


def test_paged_weight_swap_flushes_prefix_cache():
    """Prefix K/V depend on the weight version: after a hot swap, a
    repeated prompt must re-prefill (no stale-weight reuse), and tokens
    must match a fresh engine on the new weights."""
    model, params = _tiny(seed=0)
    _, params2 = _tiny(seed=1)
    prompt = [3, 1, 4, 1, 5, 9, 2, 6]
    reqs = [Request(prompt=list(prompt), max_new_tokens=5, request_id=0)]

    eng = _paged(model, params, max_slots=1)
    eng.generate(reqs)
    hits0 = _kv_stats(eng)["prefix_hits"]
    eng.store.stage(fp_params=params2, source="test", block=True)
    outs = eng.generate(reqs)
    fresh = _paged(model, params2, max_slots=1).generate(reqs)
    assert outs[0].tokens == fresh[0].tokens
    # the post-swap admission must not have hit the stale prefix cache
    assert _kv_stats(eng)["prefix_hits"] == hits0


# ---------------------------------------------------------------------------
# rewind (speculative rollback)
# ---------------------------------------------------------------------------

def _advance(kv, params, slots, target):
    """Decode junk tokens until every slot in ``slots`` sits at
    ``target`` (per-slot positions, so slots catch up independently)."""
    tok = jnp.zeros((kv.max_slots,), jnp.int32)
    while True:
        active = [i for i in slots if int(kv._lengths[i]) < target]
        if not active:
            return
        kv.decode(params, tok, active)


def test_rewind_sweep_invariants_under_cow_and_pressure():
    """Property-style sweep of the speculative rollback: rewinds of
    0..k tokens at positions straddling block boundaries, on slots whose
    prompts COW-share a prefix, in a pool small enough that admissions
    run under block pressure. After every rewind the full partition /
    refcount / reservation invariant must hold, and a rewound-across
    boundary must be re-crossable (the block went back to the slot's
    reservation, never to another slot's free list)."""
    model, params = _tiny()
    eng = _paged(model, params, max_len=32, block_size=4, max_slots=2,
                 kv_blocks=11)
    kv = eng.scheduler.kv
    sp = eng.store.current.params
    shared = [1, 2, 3, 4, 5, 6]
    r0 = Request(prompt=shared + [7], max_new_tokens=12, request_id=0)
    r1 = Request(prompt=shared + [9], max_new_tokens=12, request_id=1)
    kv.admit([(None, r0)], [0], 0, sp)
    kv.check_invariants()
    kv.admit([(None, r1)], [1], 0, sp)     # prefix hit + write-range COW
    kv.check_invariants()
    assert kv.stats()["prefix_hits"] >= 1

    for target in (8, 9, 11, 12, 13):      # around bs=4 boundaries
        for n in range(0, 5):              # rewind 0..k
            _advance(kv, sp, (0, 1), target)
            for slot in (0, 1):
                kv.rewind(slot, n)
                kv.check_invariants()
                assert int(kv._lengths[slot]) == target - n
            _advance(kv, sp, (0, 1), target)   # re-cross the boundary
            kv.check_invariants()

    kv.retire(0)
    kv.check_invariants()
    # third admission re-shares the prefix from the registry while slot 1
    # is mid-flight, then both slots rewind again under the tighter pool
    r2 = Request(prompt=shared + [11], max_new_tokens=12, request_id=2)
    kv.admit([(None, r2)], [0], 0, sp)
    kv.check_invariants()
    _advance(kv, sp, (0,), 9)
    kv.rewind(0, 2)
    kv.check_invariants()
    kv.rewind(1, 4)
    kv.check_invariants()
    kv.retire(0)
    kv.retire(1)
    kv.check_invariants()
    st = kv.stats()
    _assert_no_leaks(st)


def test_rewind_unsupported_on_contiguous_backend():
    """The lockstep cache has one shared clock: per-slot rewind must be
    a clear NotImplementedError, not silent corruption."""
    model, params = _tiny()
    eng = ServeEngine(model, params,
                      ServeConfig(max_len=32, scheduler="continuous",
                                  max_slots=2))
    with pytest.raises(NotImplementedError, match="rewind"):
        eng.scheduler.kv.rewind(0, 1)
