"""Unit tests for the vectorized SQuant core: invariants, oracle agreement
with the sequential NumPy reference (Algorithms 1-4), and MSE ordering."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.reference import squant_reference
from repro.core.squant import SQuantConfig, squant, squant_codes
from repro.quant.qtypes import qmax_for_bits
from repro.quant.scales import compute_scale

from conftest import grid_weights


def _delta(codes, w, scale):
    return np.asarray(codes, np.float64) - np.asarray(w, np.float64) / \
        np.asarray(scale, np.float64).reshape(w.shape[0], 1)


# ---------------------------------------------------------------------------
# Invariants (Eq. 9-12)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [3, 4, 6, 8])
@pytest.mark.parametrize("gs", [None, 32, 128])
def test_full_squant_invariants(rng, bits, gs):
    w = rng.normal(size=(24, 256)).astype(np.float32)
    cfg = SQuantConfig(bits=bits, group_size=gs)
    qt, stats = squant(jnp.asarray(w), cfg)
    codes = np.asarray(qt.codes(), np.float64)
    d = _delta(codes, w, qt.scale)
    tol = 1e-4
    # r_e relaxed to 1.0: every element within one grid step
    assert np.abs(d).max() < 1.0 + tol
    # r_c = 0.5: channel ASE bounded
    assert np.abs(d.sum(axis=1)).max() <= 0.5 + tol
    if gs is not None and gs < 256:
        # r_k relaxed to 1.0 after SQuant-C
        gsum = d.reshape(24, -1, gs).sum(axis=-1)
        assert np.abs(gsum).max() <= 1.0 + tol
    # codes on the symmetric grid
    assert codes.max() <= qmax_for_bits(bits)
    assert codes.min() >= -qmax_for_bits(bits)


def test_ek_only_invariants(rng):
    w = rng.normal(size=(8, 256)).astype(np.float32)
    cfg = SQuantConfig(bits=4, group_size=32, enable_c=False)
    qt, _ = squant(jnp.asarray(w), cfg)
    d = _delta(np.asarray(qt.codes()), w, qt.scale)
    gsum = d.reshape(8, -1, 32).sum(axis=-1)
    assert np.abs(gsum).max() <= 0.5 + 1e-4      # r_k = 0.5 before C
    assert np.abs(d).max() < 1.0 + 1e-4


def test_e_only_is_rounding(rng):
    w = rng.normal(size=(8, 64)).astype(np.float32)
    cfg = SQuantConfig(bits=4, group_size=16, enable_k=False, enable_c=False)
    qt, _ = squant(jnp.asarray(w), cfg)
    scale = np.asarray(qt.scale)
    expect = np.clip(np.round(w / scale), -7, 7)
    np.testing.assert_array_equal(np.asarray(qt.codes()), expect)


def test_flip_counts_match_case(rng):
    """k = ⌊|Σδ|⌉ flips per group (Algorithm 2 line 4)."""
    w = grid_weights(rng, 16, 256)
    scale = np.ones((16, 1), np.float32)
    codes, delta, stats = squant_codes(
        jnp.asarray(w), jnp.asarray(scale), bits=8, group_size=32,
        enable_k=True, enable_c=False)
    d0 = np.round(w) - w
    expected = int(np.abs(d0.reshape(16, -1, 32).sum(-1)).round().sum())
    assert int(stats["flips_k"]) == expected


# ---------------------------------------------------------------------------
# Oracle agreement: vectorized JAX == sequential NumPy (Algorithms 1-4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gs,ek,ec", [
    (None, False, True),   # paper FC path: E then C
    (32, True, False),     # E&K
    (32, True, True),      # full E&K&C
    (64, True, True),
    (32, False, True),     # E&C ablation
])
def test_matches_sequential_reference(rng, gs, ek, ec):
    w = grid_weights(rng, 12, 128)
    scale = np.ones((12, 1), np.float32) * 0.25    # grid-exact ratio
    ref_codes, ref_delta, _ = squant_reference(
        w, scale, bits=8, group_size=gs, enable_k=ek, enable_c=ec)
    codes, delta, _ = squant_codes(
        jnp.asarray(w), jnp.asarray(scale), bits=8, group_size=gs,
        enable_k=ek, enable_c=ec)
    np.testing.assert_array_equal(np.asarray(codes), ref_codes)


def test_matches_reference_conv_layout(rng):
    """(M, N, K) conv weights: kernels are the trailing dim."""
    w = grid_weights(rng, 6, 16 * 9).reshape(6, 16, 9)
    scale = np.ones((6, 1), np.float32) * 0.5
    ref_codes, _, _ = squant_reference(
        w.reshape(6, -1), scale, bits=8, group_size=9)
    qt, _ = squant(jnp.asarray(w), SQuantConfig(bits=8, group_size=None),
                   scale=jnp.asarray(scale))
    np.testing.assert_array_equal(
        np.asarray(qt.codes()).reshape(6, -1), ref_codes)


# ---------------------------------------------------------------------------
# Objective quality: CASE ordering E >= E&K >= E&K&C on the data-free metric
# ---------------------------------------------------------------------------

def test_case_ordering(rng):
    w = rng.normal(size=(32, 512)).astype(np.float32)
    scale = compute_scale(jnp.asarray(w), 4, "max")

    def row_case(codes):
        d = _delta(np.asarray(codes), w, scale)
        return np.abs(d.sum(1)).mean()

    results = {}
    for tag, (ek, ec) in {"e": (False, False), "ek": (True, False),
                          "ekc": (True, True)}.items():
        codes, _, _ = squant_codes(jnp.asarray(w), scale, bits=4,
                                   group_size=64, enable_k=ek, enable_c=ec)
        results[tag] = row_case(codes)
    assert results["ekc"] <= results["ek"] + 1e-6
    assert results["ekc"] <= results["e"] + 1e-6
    assert results["ek"] <= results["e"] + 1e-6


def test_mse_penalty_is_small(rng):
    """Flips trade a little element MSE for CASE; the MSE increase over pure
    rounding must stay tiny (each flip costs at most (1-|δ|)² - δ² < 1)."""
    w = rng.normal(size=(32, 512)).astype(np.float32)
    cfg_e = SQuantConfig(bits=4, group_size=64, enable_k=False, enable_c=False)
    cfg_f = SQuantConfig(bits=4, group_size=64)
    qe, _ = squant(jnp.asarray(w), cfg_e)
    qf, _ = squant(jnp.asarray(w), cfg_f)
    mse_e = float(np.mean((np.asarray(qe.dequantize()) - w) ** 2))
    mse_f = float(np.mean((np.asarray(qf.dequantize()) - w) ** 2))
    assert mse_f < mse_e * 1.35


def test_pathological_all_half(rng):
    """Worst case from Appendix B.1: every δ = ±0.5."""
    w = np.full((4, 64), 0.5, np.float32)
    scale = np.ones((4, 1), np.float32)
    codes, delta, _ = squant_codes(jnp.asarray(w), jnp.asarray(scale),
                                   bits=8, group_size=16, enable_k=True,
                                   enable_c=True)
    d = np.asarray(delta)
    assert np.abs(d.sum(1)).max() <= 0.5 + 1e-5
    assert np.abs(d).max() <= 1.0


def test_zero_and_tiny_rows():
    w = np.zeros((4, 64), np.float32)
    w[1, 0] = 1e-30
    qt, _ = squant(jnp.asarray(w), SQuantConfig(bits=4, group_size=16))
    assert np.all(np.isfinite(np.asarray(qt.dequantize())))


def test_boundary_clipping_respected(rng):
    """With an aggressive (clipping) scale, flips must stay on the grid."""
    w = rng.normal(size=(16, 128)).astype(np.float32) * 4
    scale = np.full((16, 1), 0.5, np.float32)   # clips heavily at 4-bit
    codes, _, _ = squant_codes(jnp.asarray(w), jnp.asarray(scale), bits=4,
                               group_size=32, enable_k=True, enable_c=True)
    c = np.asarray(codes)
    assert c.max() <= 7 and c.min() >= -7
