"""Shared test fixtures.

NOTE: no XLA_FLAGS device-count override here — smoke tests and benches must
see the single real CPU device. Multi-device tests spawn subprocesses that
set the flag themselves (see tests/test_sharding.py, tests/test_dryrun_small.py).
"""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def grid_weights(rng, m, n, step=1.0 / 64.0, span=400):
    """Weights on an exact binary grid: float32 sums are exact, so the
    vectorized JAX implementation and the float64 NumPy reference make
    identical flip decisions (no accumulation-order ambiguity)."""
    ints = rng.integers(-span, span + 1, size=(m, n))
    return (ints * step).astype(np.float32)
