"""Shared test fixtures.

NOTE: no XLA_FLAGS device-count override here — smoke tests and benches must
see the single real CPU device. Multi-device tests go through the
``multidevice_run`` fixture below, which spawns a fresh interpreter with
``--xla_force_host_platform_device_count=<N>`` appended to XLA_FLAGS
(subprocess-safe: jax locks the device count at first init, so the flag can
never be applied inside the already-initialized test process). CI's
``multidevice`` lane additionally sets the flag on the parent process and
runs only the sharded tests in-process.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def grid_weights(rng, m, n, step=1.0 / 64.0, span=400):
    """Weights on an exact binary grid: float32 sums are exact, so the
    vectorized JAX implementation and the float64 NumPy reference make
    identical flip decisions (no accumulation-order ambiguity)."""
    ints = rng.integers(-span, span + 1, size=(m, n))
    return (ints * step).astype(np.float32)


def run_multidevice_script(script: str, devices: int = 8,
                           timeout: int = 600) -> str:
    """Run ``script`` in a subprocess that sees ``devices`` host-platform
    devices. Appends to any existing XLA_FLAGS rather than clobbering them,
    and puts src/ on PYTHONPATH. Raises AssertionError with the subprocess
    stderr on non-zero exit."""
    env = dict(os.environ)
    flag = f"--xla_force_host_platform_device_count={devices}"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + flag).strip()
    env["PYTHONPATH"] = SRC + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, \
        f"--- stdout ---\n{out.stdout[-2000:]}\n--- stderr ---\n" \
        f"{out.stderr[-4000:]}"
    return out.stdout


@pytest.fixture
def multidevice_run():
    """Fixture handle for :func:`run_multidevice_script` — the harness CI's
    CPU-only runners use to genuinely exercise ≥2-device meshes."""
    return run_multidevice_script
