"""Quantized checkpoint tests: native save/restore of serving-format
``w_q``/``w_q4``/``w_scale`` trees (int4 kept packed on disk), quant
metadata validation, torn-save semantics, and reshard-on-restore
bit-exactness across 1/2/8-device meshes (quantize → save → restore on a
different mesh size → serve must produce codes, scales, and generated
tokens identical to the never-checkpointed in-memory path).
"""
import dataclasses
import json
import os
import textwrap

import jax
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer, CheckpointMetaError
from repro.configs import get_config
from repro.models.model import build_model
from repro.quant.apply import (is_quantized_tree, quant_tree_meta,
                               quantize_params_serving)


def _tiny(seed=0):
    cfg = get_config("granite-3-8b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32", n_layers=2, d_model=32,
                              n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
                              vocab=64)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(seed)), cfg


def _assert_trees_equal(a, b):
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert len(fa) == len(fb)
    for (k1, x), (k2, y) in zip(fa, fb):
        assert jax.tree_util.keystr(k1) == jax.tree_util.keystr(k2)
        assert x.dtype == y.dtype, jax.tree_util.keystr(k1)
        assert np.array_equal(np.asarray(x), np.asarray(y)), \
            jax.tree_util.keystr(k1)


@pytest.mark.parametrize("bits", [8, 4])
def test_quantized_roundtrip_bit_exact(tmp_path, bits):
    model, params, _ = _tiny()
    qtree, meta = quantize_params_serving(params, bits, "squant")
    assert is_quantized_tree(qtree) and not is_quantized_tree(params)
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save_serving(3, qtree, quant_meta=meta)
    restored, m, step = ck.restore_serving(
        expect={"quantize_weights": "squant", "weight_bits": bits})
    assert step == 3 and m["format"] == "quantized"
    assert m["quant"]["bits"] == bits and m["quant"]["method"] == "squant"
    assert m["quant"]["packed_int4"] == (bits <= 4)
    _assert_trees_equal(qtree, restored)


def test_int4_nibbles_stay_packed_on_disk(tmp_path):
    model, params, _ = _tiny()
    qtree, meta = quantize_params_serving(params, 4, "squant")
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save_serving(1, qtree, quant_meta=meta)
    data = np.load(str(tmp_path / "step_00000001" / "shard_00000.npz"))
    q4_keys = [k for k in data.files if k.endswith("w_q4")]
    assert q4_keys, "no packed int4 payload on disk"
    for k in q4_keys:
        assert data[k].dtype == np.int8            # two nibbles per byte
        scale = data[k.replace("w_q4", "w_scale")]
        # packed column count is half the logical in-dim
        assert data[k].shape[-2] == scale.shape[-2]


def test_restore_rejects_bits_and_method_mismatch(tmp_path):
    model, params, _ = _tiny()
    qtree, meta = quantize_params_serving(params, 4, "squant")
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save_serving(1, qtree, quant_meta=meta)
    with pytest.raises(CheckpointMetaError, match="mismatch"):
        ck.restore_serving(expect={"quantize_weights": "squant",
                                   "weight_bits": 8})
    with pytest.raises(CheckpointMetaError, match="mismatch"):
        ck.restore_serving(expect={"quantize_weights": "rtn",
                                   "weight_bits": 4})
    with pytest.raises(CheckpointMetaError, match="unquantized"):
        ck.restore_serving(expect={"quantize_weights": None,
                                   "weight_bits": 8})
    # matching expectations (or none at all) load fine
    ck.restore_serving(expect={"quantize_weights": "squant",
                               "weight_bits": 4})
    ck.restore_serving()


def test_training_restore_rejects_quantized_checkpoint(tmp_path):
    model, params, _ = _tiny()
    qtree, meta = quantize_params_serving(params, 8, "squant")
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save_serving(1, qtree, quant_meta=meta)
    with pytest.raises(CheckpointMetaError, match="restore_serving"):
        ck.restore(1, template=(params, {}))


def test_save_serving_requires_bits_and_method(tmp_path):
    model, params, _ = _tiny()
    qtree, _ = quantize_params_serving(params, 8, "squant")
    ck = Checkpointer(str(tmp_path), async_save=False)
    with pytest.raises(ValueError, match="quant_meta missing"):
        ck.save_serving(1, qtree, quant_meta={"bits": 8})


@pytest.mark.parametrize("kind", ["fp", "quantized"])
def test_restore_skips_torn_and_corrupt_steps(tmp_path, kind):
    model, params, _ = _tiny(0)
    _, params2, _ = _tiny(1)
    ck = Checkpointer(str(tmp_path), async_save=False)

    def save(step, tree):
        if kind == "fp":
            ck.save_serving(step, tree)
        else:
            q, m = quantize_params_serving(tree, 8, "squant")
            ck.save_serving(step, q, quant_meta=m)

    save(1, params)
    save(2, params2)
    save(3, params2)
    os.remove(str(tmp_path / "step_00000002" / "COMMITTED"))     # torn
    with open(str(tmp_path / "step_00000003" / "index.json"), "w") as f:
        f.write('{"trees": ')                                    # corrupt
    assert ck.list_steps() == [1]
    _, meta, step = ck.restore_serving()
    assert step == 1
    with pytest.raises(CheckpointMetaError, match="COMMITTED"):
        ck.read_meta(2)
    with pytest.raises(CheckpointMetaError, match="index.json"):
        ck.read_meta(3)


def test_gc_removes_invalid_step_dirs(tmp_path):
    """Torn/corrupt step dirs are invisible to restore but must still be
    garbage-collected, or they leak one model-sized dir per occurrence."""
    model, params, _ = _tiny()
    ck = Checkpointer(str(tmp_path), async_save=False, keep=2)
    ck.save_serving(1, params)
    ck.save_serving(2, params)
    os.remove(str(tmp_path / "step_00000001" / "COMMITTED"))     # now torn
    ck.save_serving(3, params)                                   # runs _gc
    assert not (tmp_path / "step_00000001").exists()
    assert ck.list_steps() == [2, 3]


def test_quant_tree_meta_report_digest():
    from repro.core.pipeline import quantize_tree
    model, params, _ = _tiny()
    _, report = quantize_tree(params, method="squant", bits=8,
                              dequantize=True)
    meta = quant_tree_meta(8, "squant", 128, report=report)
    assert meta["report"]["layers"] == len(report.layers)
    assert meta["report"]["backend"] == report.backend
    json.dumps(meta)                                 # index.json-safe


# ---------------------------------------------------------------------------
# Reshard-on-restore: 1/2/8 virtual devices, bit-exact + token-identical
# ---------------------------------------------------------------------------

_RESHARD_SCRIPT = textwrap.dedent("""
    import dataclasses, tempfile
    import jax, numpy as np
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.quant.apply import quantize_params_serving
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.launch.mesh import make_quantize_mesh
    from repro.serving.engine import Request, ServeConfig, ServeEngine
    from repro.serving.weights import WeightStore

    assert len(jax.devices()) == {devices}
    cfg = dataclasses.replace(get_config("granite-3-8b", reduced=True),
                              dtype="float32", n_layers=2, d_model=32,
                              n_heads=2, n_kv_heads=1, head_dim=16,
                              d_ff=64, vocab=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qtree, meta = quantize_params_serving(params, {bits}, "squant")
    with tempfile.TemporaryDirectory() as tmp:
        ck = Checkpointer(tmp, async_save=False)
        ck.save_serving(1, qtree, quant_meta=meta)
        restored, m, _ = ck.restore_serving(
            expect={{"quantize_weights": "squant", "weight_bits": {bits}}},
            mesh=make_quantize_mesh())
    fa = jax.tree_util.tree_flatten_with_path(qtree)[0]
    fb = jax.tree_util.tree_flatten_with_path(restored)[0]
    assert len(fa) == len(fb)
    for (k1, a), (k2, b) in zip(fa, fb):
        assert jax.tree_util.keystr(k1) == jax.tree_util.keystr(k2)
        assert np.array_equal(np.asarray(a), np.asarray(b)), \\
            jax.tree_util.keystr(k1)

    def toks(tree):
        eng = ServeEngine(model, cfg=ServeConfig(max_batch=2, max_len=32),
                          store=WeightStore(serving_params=tree))
        return [c.tokens for c in eng.generate(
            [Request(prompt=[1, 2, 3], max_new_tokens=6, request_id=i)
             for i in range(2)])]

    assert toks(qtree) == toks(restored)
    print("RESHARD_OK", {devices}, {bits})
""")


@pytest.mark.parametrize("devices,bits", [(1, 4), (2, 4), (8, 4), (2, 8)])
def test_reshard_on_restore_multidevice(multidevice_run, devices, bits):
    """Quantize → save → restore onto a different mesh size → serve:
    codes/scales bit-exact and greedy tokens identical to the in-memory
    tree (the checkpoint stores full logical arrays; device_put re-splits
    them)."""
    out = multidevice_run(_RESHARD_SCRIPT.format(devices=devices,
                                                 bits=bits),
                          devices=devices)
    assert f"RESHARD_OK {devices} {bits}" in out


def test_reshard_on_restore_inprocess(tmp_path):
    """Same contract on the real in-process device set — CI's multidevice
    lane (8 virtual devices) and its 2-device reshard step run this against
    genuinely sharded restores; single-device runs cover the trivial
    mesh."""
    from repro.launch.mesh import make_quantize_mesh
    from repro.serving.engine import Request, ServeConfig, ServeEngine
    from repro.serving.weights import WeightStore

    model, params, _ = _tiny()
    qtree, meta = quantize_params_serving(params, 4, "squant")
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save_serving(1, qtree, quant_meta=meta)
    restored, _, _ = ck.restore_serving(
        expect={"quantize_weights": "squant", "weight_bits": 4},
        mesh=make_quantize_mesh())
    _assert_trees_equal(qtree, restored)

    def toks(tree):
        eng = ServeEngine(model, cfg=ServeConfig(max_batch=2, max_len=32),
                          store=WeightStore(serving_params=tree))
        return [c.tokens for c in eng.generate(
            [Request(prompt=[1, 2, 3], max_new_tokens=6, request_id=i)
             for i in range(2)])]

    assert toks(qtree) == toks(restored)
