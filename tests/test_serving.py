"""Serving engine tests: batched generation, on-the-fly quantized serving,
int8 KV caches, multi-round batching."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.serving.engine import Request, ServeConfig, ServeEngine


def _model(arch="granite-3-8b", **over):
    cfg = get_config(arch, reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32", **over)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params, cfg


def test_batched_generation_shapes():
    model, params, cfg = _model()
    eng = ServeEngine(model, params, ServeConfig(max_batch=4, max_len=64))
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=5, request_id=i)
            for i in range(6)]                      # forces two rounds
    outs = eng.generate(reqs)
    assert len(outs) == 6
    assert all(len(o.tokens) == 5 for o in outs)
    assert all(0 <= t < cfg.vocab for o in outs for t in o.tokens)


def test_greedy_deterministic():
    model, params, _ = _model()
    eng = ServeEngine(model, params, ServeConfig(max_batch=2, max_len=64))
    r = [Request(prompt=[5, 6, 7], max_new_tokens=8)]
    a = eng.generate(r)[0].tokens
    b = eng.generate(r)[0].tokens
    assert a == b


def test_quantized_serving_w8_close_to_fp():
    """Teacher-forced logit deltas under w8 SQuant stay far below the logit
    scale (free-running greedy on an untrained model diverges at near-ties,
    so the comparison is per-step)."""
    model, params, _ = _model()
    q8 = ServeEngine(model, params,
                     ServeConfig(max_batch=2, max_len=64,
                                 quantize_weights="squant", weight_bits=8))
    assert q8.quant_report is not None and q8.quant_report.layers
    batch = {"tokens": jnp.asarray([[5, 6, 7, 9, 2]], jnp.int32)}
    c1, c2 = model.init_cache(1, 16), model.init_cache(1, 16)
    l1, c1 = model.prefill(params, batch, c1)
    l2, c2 = model.prefill(q8.params, batch, c2)
    scale = float(np.abs(np.asarray(l1)).max())
    assert float(np.abs(np.asarray(l1) - np.asarray(l2)).max()) < 0.05 * scale
    for t in (3, 1, 4):
        tok = jnp.asarray([[t]], jnp.int32)
        l1, c1 = model.decode_step(params, tok, c1)
        l2, c2 = model.decode_step(q8.params, tok, c2)
        assert float(np.abs(np.asarray(l1) - np.asarray(l2)).max()) \
            < 0.05 * scale


def test_quantized_serving_methods_run():
    model, params, _ = _model()
    for method in ("rtn", "squant", "squant_ek"):
        eng = ServeEngine(model, params,
                          ServeConfig(max_batch=2, max_len=48,
                                      quantize_weights=method,
                                      weight_bits=4))
        outs = eng.generate([Request(prompt=[1, 2], max_new_tokens=4)])
        assert len(outs[0].tokens) == 4


def test_int8_kv_cache_close_to_fp():
    """Teacher-forced decode with int8 KV tracks the fp cache closely."""
    model, params, _ = _model()
    batch = {"tokens": jnp.asarray([[9, 8, 7, 6]], jnp.int32)}
    c1 = model.init_cache(1, 16, quantize_kv=False)
    c2 = model.init_cache(1, 16, quantize_kv=True)
    l1, c1 = model.prefill(params, batch, c1)
    l2, c2 = model.prefill(params, batch, c2)
    scale = float(np.abs(np.asarray(l1)).max())
    for t in (3, 1, 4, 1):
        tok = jnp.asarray([[t]], jnp.int32)
        l1, c1 = model.decode_step(params, tok, c1)
        l2, c2 = model.decode_step(params, tok, c2)
        assert float(np.abs(np.asarray(l1) - np.asarray(l2)).max()) \
            < 0.08 * scale


def test_moe_and_rwkv_serving():
    for arch in ("mixtral-8x7b", "rwkv6-1.6b"):
        model, params, _ = _model(arch)
        eng = ServeEngine(model, params,
                          ServeConfig(max_batch=2, max_len=48))
        outs = eng.generate([Request(prompt=[3, 1, 4], max_new_tokens=4)])
        assert len(outs[0].tokens) == 4


def test_quantized_expert_serving():
    """QuantizedTensor expert banks serve without dequantize_for_compute."""
    model, params, _ = _model("mixtral-8x7b")
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_len=48,
                                  quantize_weights="squant", weight_bits=8,
                                  dequantize_for_compute=False))
    outs = eng.generate([Request(prompt=[3, 1, 4], max_new_tokens=3)])
    assert len(outs[0].tokens) == 3
