"""Pallas dequant_matmul kernel vs pure-jnp oracle: int8/int4, per-channel
and per-group scales, shape sweeps, interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.squant import SQuantConfig, squant
from repro.kernels import ops, ref
from repro.kernels.dequant_matmul import dequant_matmul_pallas
from repro.quant.qtypes import pack_int4


def _quant(rng, m, n, bits, group_scales=False, group_size=32):
    codes = rng.integers(-(2 ** (bits - 1) - 1), 2 ** (bits - 1),
                         size=(m, n)).astype(np.int8)
    if group_scales:
        scale = rng.uniform(0.01, 0.1, size=(m, n // group_size)
                            ).astype(np.float32)
    else:
        scale = rng.uniform(0.01, 0.1, size=(m, 1)).astype(np.float32)
    data = np.asarray(pack_int4(jnp.asarray(codes))) if bits <= 4 else codes
    return jnp.asarray(data), jnp.asarray(scale), codes


@pytest.mark.parametrize("b,m,n,g", [
    (8, 16, 64, 32),
    (4, 32, 128, 32),
    (16, 8, 256, 64),
    (2, 128, 128, 128),
    (1, 4, 32, 32),
])
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("group_scales", [False, True])
def test_matches_ref(rng, b, m, n, g, bits, group_scales):
    data, scale, codes = _quant(rng, m, n, bits, group_scales, g)
    x = jnp.asarray(rng.normal(size=(b, n)).astype(np.float32))
    got = dequant_matmul_pallas(x, data, scale, bits=bits, group_size=g,
                                tb=min(8, b), tm=min(8, m), interpret=True)
    want = ref.dequant_matmul_ref(x, data, scale, bits=bits, group_size=g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_matches_dense_matmul(rng):
    """End-to-end: x @ dequant(W).T computed three ways."""
    w = rng.normal(size=(32, 128)).astype(np.float32)
    qt, _ = squant(jnp.asarray(w), SQuantConfig(bits=4, group_size=32))
    x = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
    dense = np.asarray(x) @ np.asarray(qt.dequantize()).T
    via_ops = ops.dequant_matmul(x, qt, group_size=32, use_pallas="interpret")
    via_ref = ops.dequant_matmul(x, qt, group_size=32, use_pallas="ref")
    np.testing.assert_allclose(np.asarray(via_ops), dense, rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(via_ref), dense, rtol=1e-4,
                               atol=1e-4)


def test_bf16_activations(rng):
    data, scale, _ = _quant(rng, 16, 64, 8)
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32)
                    ).astype(jnp.bfloat16)
    got = dequant_matmul_pallas(x, data, scale, bits=8, group_size=32,
                                tb=8, tm=8, interpret=True)
    want = ref.dequant_matmul_ref(x, data, scale, bits=8, group_size=32)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=2e-2,
                               atol=2e-2)


def test_int4_packing_consistency(rng):
    """The kernel's in-VMEM nibble unpack matches qtypes.unpack_int4."""
    from repro.kernels.dequant_matmul import _unpack_nibbles
    from repro.quant.qtypes import unpack_int4
    codes = rng.integers(-8, 8, size=(4, 32)).astype(np.int8)
    packed = pack_int4(jnp.asarray(codes))
    np.testing.assert_array_equal(np.asarray(_unpack_nibbles(packed)),
                                  np.asarray(unpack_int4(packed)))
