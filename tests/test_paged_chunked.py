"""Paged KV cache × chunked admission (PR 7).

The lifted gate: under ``kv_backend="paged"`` a chunked admission has no
shared clock to catch up to — each pending entry's completion target is
its OWN prompt length, chunks run on a 1-row side cache at monolithic-
admission shapes (batch 1, unpadded), and completion scatters into the
slot's reserved blocks. Tokens are therefore position-deterministic:
bit-identical to monolithic paged AND the solo contiguous oracle for
EVERY chunk split, regardless of admission timing.

The bug-shaped seams this file pins down:

* force-swap abandon must release reserved blocks and unpin shared-prefix
  blocks (the contiguous abandon just drops the side cache — under paged
  that leaks until pool exhaustion);
* shared-prefix blocks must be pinned BEFORE the first chunk step, so
  FIFO eviction under pool pressure between chunk steps can never recycle
  a block the pending gathered from.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.serving.engine import Request, ServeConfig, ServeEngine


def _tiny(seed=0, vocab=256, **over):
    cfg = get_config("granite-3-8b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32", n_layers=2, d_model=32,
                              n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
                              vocab=vocab, **over)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(seed))


def _paged(model, params, **over):
    base = dict(max_len=32, scheduler="continuous", max_slots=2,
                kv_backend="paged", block_size=4)
    base.update(over)
    return ServeEngine(model, params, ServeConfig(**base))


def _solo_oracle(model, params, reqs, max_len=32):
    out = {}
    for r in reqs:
        eng = ServeEngine(model, params,
                          ServeConfig(max_batch=1, max_len=max_len))
        out[r.request_id] = eng.generate([r])[0].tokens
    return out


def _kv_stats(eng):
    return eng.scheduler.stats()["kv"]


def _assert_block_invariant(eng):
    """The stats()-level block invariant (free + cached + active + trash
    == num_blocks) plus the full internal consistency check."""
    kv = _kv_stats(eng)
    assert (kv["blocks_free"] + kv["blocks_cached"] + kv["blocks_active"]
            + kv["blocks_trash"]) == kv["blocks_total"]
    eng.scheduler.kv.check_invariants()


def _assert_no_leaks(eng):
    kv = _kv_stats(eng)
    assert kv["blocks_active"] == 0
    assert kv["blocks_reserved"] == 0
    _assert_block_invariant(eng)


def _stage_at_step(eng, step, params2):
    def hook(info):
        if info["step"] == step and not eng.store.staged_pending:
            eng.store.stage(fp_params=params2, source="midrun", block=True)
    eng.on_step = hook


# ---------------------------------------------------------------------------
# property-style chunk-split sweep: bit-identity for every split
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sweep_setup():
    model, params = _tiny()
    # mixed lengths + staggered budgets over 2 slots: retirements
    # interleave, so later admissions happen mid-flight while a resident
    # decodes (the case the contiguous backend cannot chunk at chunk=1)
    reqs = [Request(prompt=[1, 2, 3, 4, 5, 6], max_new_tokens=8,
                    request_id=0),
            Request(prompt=[7, 8], max_new_tokens=3, request_id=1),
            Request(prompt=[9, 10, 11, 12, 13, 14, 15, 16, 17, 18, 19],
                    max_new_tokens=5, request_id=2),
            Request(prompt=[4, 3, 2], max_new_tokens=6, request_id=3)]
    oracle = _solo_oracle(model, params, reqs)
    mono = {c.request_id: c.tokens
            for c in _paged(model, params).generate(reqs)}
    return model, params, reqs, oracle, mono


@pytest.mark.parametrize("chunk", [1, 3, 5, 64])
def test_chunk_split_sweep_bit_identical(sweep_setup, chunk):
    """chunk=1 (every chunk a padded singleton), chunk=3/5 (non-dividing),
    chunk=64 (>= every prompt: one-chunk pendings) — all bit-identical to
    monolithic paged and the solo contiguous oracle."""
    model, params, reqs, oracle, mono = sweep_setup
    eng = _paged(model, params, prefill_chunk=chunk)
    outs = eng.generate(reqs)
    for c in outs:
        assert c.tokens == oracle[c.request_id], f"chunk={chunk} vs oracle"
        assert c.tokens == mono[c.request_id], f"chunk={chunk} vs monolithic"
    sch = eng.scheduler.stats()
    assert sch["admitted"] == 4 and sch["retired"] == 4
    assert sch["pendings_started"] >= 2       # fresh wave + mid-flight
    assert sch["pendings_abandoned"] == 0
    expected_chunks = sum(-(-len(r.prompt) // chunk) for r in reqs)
    # prefix reuse can only shrink suffixes, never add chunk steps
    assert 0 < sch["chunk_steps"] <= expected_chunks
    _assert_no_leaks(eng)


def test_midflight_admission_with_residents_chunk1():
    """The headline case the contiguous backend cannot serve: a long
    prompt admitted at chunk=1 while a resident decodes. No catch-up
    recurrence — the pending completes at its own prompt length after
    exactly ceil(L/1) chunk steps."""
    model, params = _tiny()
    resident = Request(prompt=[1, 2], max_new_tokens=14, request_id=0)
    long_req = Request(prompt=list(range(2, 15)), max_new_tokens=4,
                       request_id=1)
    oracle = _solo_oracle(model, params, [resident, long_req])
    eng = _paged(model, params, max_slots=1, prefill_chunk=1)
    outs = eng.generate([resident, long_req])
    for c in outs:
        assert c.tokens == oracle[c.request_id]
    adm = {e["request_id"]: e for e in eng.scheduler.admission_log}
    assert adm[1]["chunks"] == len(long_req.prompt)
    assert adm[1]["clock"] == len(long_req.prompt)   # per-slot position
    _assert_no_leaks(eng)


def test_trace_counts_one_trace_per_chunk_length():
    """One ``prefill_chunk`` trace per distinct chunk width (jit keys on
    the input shape, so a singleton chunk is its own specialization even
    though it pads to two rows inside the trace), one decode trace, zero
    monolithic prefills — and a repeated same-shape run adds no traces."""
    model, params = _tiny()
    reqs = [Request(prompt=[11, 12, 13, 14, 15, 16, 17], max_new_tokens=3,
                    request_id=0),
            Request(prompt=[21, 22, 23, 24, 25], max_new_tokens=3,
                    request_id=1)]
    eng = _paged(model, params, prefill_chunk=3)
    eng.generate(reqs)
    tc = eng.trace_counts
    # widths: 7 -> 3,3,1; 5 -> 3,2  => {3, 2, 1}
    assert tc["prefill"] == 0
    assert tc["prefill_chunk"] == 3
    assert tc["decode"] == 1
    assert eng.scheduler.stats()["chunk_steps"] == 3 + 2
    # second run re-chunks the unshared suffixes through the registry;
    # the third repeats the second's shapes exactly: zero new traces
    eng.generate(reqs)
    snap = dict(eng.trace_counts)
    eng.generate(reqs)
    assert eng.trace_counts == snap
    _assert_no_leaks(eng)


def test_shared_prefix_chat_turn_chunks_suffix_only():
    """A second turn sharing the first turn's prompt gathers the pinned
    full prefix blocks (8 of 10 tokens — the partial tail block is freed
    with its owning slot) and chunk-prefills only the remaining 5-token
    suffix: ceil(5/2) = 3 chunks instead of ceil(13/2) = 7."""
    model, params = _tiny()
    turn1 = Request(prompt=list(range(1, 11)), max_new_tokens=4,
                    request_id=0)
    turn2 = Request(prompt=list(range(1, 11)) + [51, 52, 53],
                    max_new_tokens=4, request_id=1)
    oracle = _solo_oracle(model, params, [turn1, turn2])
    eng = _paged(model, params, prefill_chunk=2)
    assert eng.generate([turn1])[0].tokens == oracle[0]
    outs = eng.generate([turn2])
    assert outs[0].tokens == oracle[1]
    kv = _kv_stats(eng)
    assert kv["prefix_hits"] >= 1
    assert kv["prefix_tokens_reused"] >= 8
    adm = [e for e in eng.scheduler.admission_log if e["request_id"] == 1]
    assert adm[-1]["chunks"] == 3
    _assert_no_leaks(eng)


# ---------------------------------------------------------------------------
# force-swap abandon: reserved blocks released, prefix pins dropped
# ---------------------------------------------------------------------------

def test_repeated_force_swap_abandons_release_blocks_and_pins():
    """A deadline force-swap abandons the in-flight pending entry while it
    holds shared-prefix pins and a full block reservation. Repeatedly: the
    block invariant must hold after every abandon (the leak this PR fixes
    — reserved blocks and pin refcounts used to survive the abandon), and
    the re-admitted request's tokens must match the solo oracle on the new
    weights."""
    model, params = _tiny(0)
    staged_params = [_tiny(s)[1] for s in (1, 2, 3)]
    resident = Request(prompt=list(range(1, 9)), max_new_tokens=12,
                       request_id=0)
    eng = _paged(model, params, prefill_chunk=1, swap_deadline_ms=0.0)
    for it, p2 in enumerate(staged_params):
        # per-iteration suffix: a repeated tail would be fully registered
        # by the previous iteration, shrinking the pending below the
        # staging step
        tail = [61 + 10 * it + j for j in range(6)]
        follower = Request(prompt=list(range(1, 9)) + tail,
                           max_new_tokens=4, request_id=1)
        # fresh wave: entry 0 (resident) completes and decodes while entry
        # 1 (follower, 8-token shared prefix -> 2 pinned blocks, 6-token
        # suffix at chunk=1) is mid-pending when the stage lands at step 2
        _stage_at_step(eng, eng.scheduler.steps_total + 2, p2)
        outs = eng.generate([resident, follower])
        sch = eng.scheduler.stats()
        assert sch["pendings_abandoned"] == it + 1
        assert sch["forced_swaps"] == it + 1
        assert outs[0].forced_swaps == 1
        # re-admitted post-swap on the new version, chunked from scratch
        # (the registry flushed with the swap), still oracle-identical
        assert outs[1].weights_version == outs[0].weights_version + 1
        oracle = _solo_oracle(model, p2, [follower])
        assert outs[1].tokens == oracle[1]
        assert len(outs[0].tokens) == resident.max_new_tokens
        _assert_no_leaks(eng)


def test_drain_waits_on_paged_pending_no_abandon():
    """With no deadline, a staged version drains the pending like any
    in-flight work: every entry completes on the old version, nothing is
    abandoned, and the block accounting stays clean."""
    model, params = _tiny(0)
    _, params2 = _tiny(1)
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=3, request_id=0),
            Request(prompt=list(range(5, 17)), max_new_tokens=8,
                    request_id=1),
            Request(prompt=[21, 22], max_new_tokens=4, request_id=2)]
    eng = _paged(model, params, prefill_chunk=2, swap_deadline_ms=None)
    _stage_at_step(eng, 2, params2)
    outs = eng.generate(reqs)
    sch = eng.scheduler.stats()
    assert sch["pendings_abandoned"] == 0
    assert sch["forced_swaps"] == 0
    assert all(len(o.tokens) == r.max_new_tokens
               for o, r in zip(outs, reqs))
    _assert_no_leaks(eng)


# ---------------------------------------------------------------------------
# pin-before-first-chunk vs FIFO eviction under pool pressure
# ---------------------------------------------------------------------------

def test_pins_survive_eviction_between_chunk_steps():
    """White-box: begin a chunked admission over a registered prefix, then
    exhaust the pool between its chunk steps. Eviction may only take the
    UNPINNED cached block; with nothing evictable left, allocation must
    fail loudly rather than recycle a pinned block — and the pending still
    completes with the oracle's greedy continuation."""
    model, params = _tiny()
    eng = _paged(model, params, max_slots=2, prefill_chunk=1)
    seed_req = Request(prompt=list(range(1, 13)), max_new_tokens=4,
                       request_id=0)
    eng.generate([seed_req])                  # registers 3 full blocks
    kv = eng.scheduler.kv
    assert _kv_stats(eng)["blocks_cached"] == 3

    follow = Request(prompt=list(range(1, 9)) + [41, 42, 43],
                     max_new_tokens=4, request_id=1)
    params_tree = eng.store.acquire()[0].params
    kv.reserve_pending(0, follow)
    lp, side = kv.begin_chunked_admit(0, follow)
    assert lp == 8                            # 2 of the 3 blocks pinned
    assert _kv_stats(eng)["blocks_cached"] == 1

    # pool pressure between chunk steps: drain the free list, then force
    # one eviction — it must take the unpinned cached block, after which
    # the pool is exhausted (pinned blocks are NOT evictable)
    taken = [kv._alloc() for _ in range(len(kv._free))]
    evicted = kv._alloc()
    taken.append(evicted)
    assert kv.evictions == 1
    assert _kv_stats(eng)["blocks_cached"] == 0
    with pytest.raises(RuntimeError, match="exhausted"):
        kv._alloc()
    for ph in taken:                          # release the pressure
        kv._unref(ph)

    logits = None
    for t in follow.prompt[lp:]:
        toks = jnp.asarray(np.asarray([[t]], np.int32))
        logits, side = eng._prefill_chunk(params_tree, {"tokens": toks},
                                          side)
    kv.complete_chunked_admit(0, follow, lp, side, logits)
    kv.check_invariants()
    # the pinned prefix survived the eviction: the slot's first greedy
    # token matches the solo oracle's
    oracle = _solo_oracle(model, params, [follow])
    assert int(np.argmax(np.asarray(kv.logits[0]))) == oracle[1][0]
    kv.retire(0)
    _assert_no_leaks(eng)


def test_eviction_pressure_end_to_end_tokens_still_identical():
    """End-to-end: a pool sized so resident decode allocations must evict
    the one unpinned cached block while a shared-prefix chunked admission
    is in flight. Eviction happens (the pool is exactly one block short),
    tokens stay oracle-identical, and the accounting balances."""
    model, params = _tiny()
    eng = _paged(model, params, max_slots=2, kv_blocks=9, prefill_chunk=1)
    seed_req = Request(prompt=list(range(1, 13)), max_new_tokens=4,
                       request_id=0)
    oracle0 = _solo_oracle(model, params, [seed_req])
    assert eng.generate([seed_req])[0].tokens == oracle0[0]
    assert _kv_stats(eng)["blocks_cached"] == 3

    resident = Request(prompt=list(range(21, 27)), max_new_tokens=10,
                       request_id=1)
    follow = Request(prompt=list(range(1, 9)) + [41, 42, 43, 44, 45, 46],
                     max_new_tokens=2, request_id=2)
    oracle = _solo_oracle(model, params, [resident, follow])
    outs = eng.generate([resident, follow])
    for c in outs:
        assert c.tokens == oracle[c.request_id]
    kv = _kv_stats(eng)
    assert kv["evictions"] >= 1
    assert kv["prefix_hits"] >= 1
    _assert_no_leaks(eng)
