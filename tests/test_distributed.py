"""Distributed tests (run in subprocesses with XLA host-device overrides so
the main test process keeps a single device): sharding rules, int8 cross-pod
gradient all-reduce, pod-compressed training, elastic checkpoint resharding.

All mesh/shard_map construction goes through ``repro.distributed.compat`` so
the same scripts run on jax 0.4.x and on the newer axis-typed API.
"""
import textwrap



def test_param_sharding_rules(multidevice_run):
    out = multidevice_run(textwrap.dedent("""
        import warnings; warnings.filterwarnings("ignore")
        import jax
        from repro.distributed.compat import make_mesh
        from repro.distributed.sharding import make_param_shardings
        S = jax.ShapeDtypeStruct
        f32 = jax.numpy.float32
        mesh = make_mesh((2, 4), ("data", "model"))
        fake = {
            "attn": {"wq": {"w": S((64, 128), f32)},
                     "wo": {"w": S((128, 64), f32)}},
            "moe": {"wi": {"w": S((8, 64, 32), f32)},
                    "router": {"w": S((64, 8), f32)}},
            "moe_odd": {"moe": {"wi": {"w": S((6, 64, 32), f32)}}},
            "periods": {"ffn": {"wi": {"w": S((3, 64, 32), f32)}}},
            "embedding": {"embedding": S((256, 64), f32)},
            "norm": {"gain": S((64,), f32)},
            "lm_head": {"w": S((64, 256), f32)},
        }
        sh = make_param_shardings(mesh, fake)
        print("wq", sh["attn"]["wq"]["w"].spec)
        print("wo", sh["attn"]["wo"]["w"].spec)
        print("moe", sh["moe"]["wi"]["w"].spec)
        print("moe_odd", sh["moe_odd"]["moe"]["wi"]["w"].spec)
        print("stacked", sh["periods"]["ffn"]["wi"]["w"].spec)
        print("emb", sh["embedding"]["embedding"].spec)
        print("gain", sh["norm"]["gain"].spec)
        print("head", sh["lm_head"]["w"].spec)
    """))
    assert "wq PartitionSpec('data', 'model')" in out
    assert "wo PartitionSpec('model', 'data')" in out
    # 8 experts divide model=4 → experts take TP, fsdp on d_in
    assert "moe PartitionSpec('model', 'data'" in out
    # 6 experts do NOT divide model=4 → expert ff dim takes TP
    assert "moe_odd PartitionSpec(None, 'data', 'model')" in out
    # scanned stack: period dim replicated, (in,out) rules shifted right
    assert "stacked PartitionSpec(None, 'data', 'model')" in out
    assert "emb PartitionSpec('model', 'data')" in out
    assert "gain PartitionSpec(None,)" in out
    assert "head PartitionSpec('data', 'model')" in out


def test_int8_ring_allreduce(multidevice_run):
    out = multidevice_run(textwrap.dedent("""
        import warnings; warnings.filterwarnings("ignore")
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compat import make_mesh, shard_map
        from repro.training.grad_compression import ring_allreduce_i8, BLOCK
        mesh = make_mesh((4,), ("pod",))
        rng = np.random.default_rng(0)
        xs = rng.normal(size=(4, 4 * BLOCK * 2)).astype(np.float32)
        f = shard_map(lambda x: ring_allreduce_i8(x[0], "pod", 4)[None],
                      mesh, in_specs=P("pod"), out_specs=P("pod"))
        got = np.asarray(f(jnp.asarray(xs)))
        want = xs.sum(0)
        rel = np.abs(got - want).max() / np.abs(want).max()
        print("REL", rel)
        print("IDENTICAL", all(np.array_equal(got[i], got[0])
                               for i in range(4)))
    """), devices=4)
    rel = float(out.split("REL ")[1].split()[0])
    assert rel < 0.03             # int8 wire quantization error
    assert "IDENTICAL True" in out


def test_pod_compressed_training_learns(multidevice_run):
    """Pod-compressed step trains the tiny model comparably to plain DP."""
    out = multidevice_run(textwrap.dedent("""
        import warnings; warnings.filterwarnings("ignore")
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.data.synthetic import markov_batches
        from repro.distributed.compat import activate_mesh, make_mesh
        from repro.models.model import build_model
        from repro.training.optimizer import AdamWConfig, adamw_init
        from repro.training.train_loop import (init_pod_error,
                                               make_train_step)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_config("granite-3-8b", reduced=True)
        cfg = dataclasses.replace(cfg, dtype="float32", n_layers=2,
                                  d_model=32, n_heads=2, n_kv_heads=1,
                                  head_dim=16, d_ff=64, vocab=64)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        ocfg = AdamWConfig(lr=3e-3, warmup_steps=0, decay_steps=100)
        activate_mesh(mesh)
        plain = jax.jit(make_train_step(model, ocfg))
        comp = jax.jit(make_train_step(model, ocfg, pod_compress=True,
                                       mesh=mesh))
        it = (jax.tree_util.tree_map(jnp.asarray, b)
              for b in markov_batches(8, 32, cfg.vocab, seed=1))
        pp, po = params, adamw_init(params)
        cp, co = params, adamw_init(params)
        err = init_pod_error(params, 2)
        err_shapes = [e.shape for e in jax.tree_util.tree_leaves(err)]
        pl, cl = [], []
        for i in range(60):
            b = next(it)
            pp, po, m1 = plain(pp, po, b)
            cp, co, err, m2 = comp(cp, co, err, b)
            pl.append(float(m1["loss"])); cl.append(float(m2["loss"]))
        # error-feedback buffers keep the init_pod_error layout step to
        # step (a shape drift would silently retrace the jitted step)
        assert [e.shape for e in jax.tree_util.tree_leaves(err)] \
            == err_shapes
        print("PLAIN", np.mean(pl[:5]), np.mean(pl[-5:]))
        print("COMP", np.mean(cl[:5]), np.mean(cl[-5:]))
    """), devices=8, timeout=900)
    plain0, plain1 = [float(x) for x in out.split("PLAIN ")[1].split()[:2]]
    comp0, comp1 = [float(x) for x in out.split("COMP ")[1].split()[:2]]
    assert plain1 < plain0 * 0.8
    assert comp1 < comp0 * 0.8                    # compression still learns
    assert abs(comp1 - plain1) < 0.25 * plain0    # and tracks plain DP


def test_elastic_checkpoint_reshard(multidevice_run):
    """Save on an 8-device mesh, restore onto a 4-device mesh."""
    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        save = textwrap.dedent(f"""
            import warnings; warnings.filterwarnings("ignore")
            import numpy as np, jax, jax.numpy as jnp
            from repro.checkpoint.checkpointer import Checkpointer
            from repro.distributed.sharding import make_param_shardings
            from repro.runtime.elastic import make_elastic_mesh
            mesh = make_elastic_mesh(8, prefer_model=4)
            params = {{"layer": {{"wq": jnp.arange(64*32, dtype=jnp.float32)
                                 .reshape(64, 32)}}}}
            sh = make_param_shardings(mesh, params)
            params = jax.device_put(params, sh)
            ck = Checkpointer("{tmp}", async_save=False)
            ck.save(7, params, {{"step": jnp.asarray(7)}})
            print("SAVED", mesh.devices.shape)
        """)
        multidevice_run(save, devices=8)
        restore = textwrap.dedent(f"""
            import warnings; warnings.filterwarnings("ignore")
            import numpy as np, jax, jax.numpy as jnp
            from repro.checkpoint.checkpointer import Checkpointer
            from repro.distributed.sharding import make_param_shardings
            from repro.runtime.elastic import make_elastic_mesh
            mesh = make_elastic_mesh(4, prefer_model=2)
            tmpl_p = {{"layer": {{"wq": jax.ShapeDtypeStruct((64, 32),
                                                             jnp.float32)}}}}
            tmpl_o = {{"step": jax.ShapeDtypeStruct((), jnp.int32)}}
            sh_p = make_param_shardings(mesh, tmpl_p)
            ck = Checkpointer("{tmp}")
            params, opt, step = ck.restore_latest(
                shardings=(sh_p, None), template=(tmpl_p, tmpl_o))
            w = params["layer"]["wq"]
            ok = np.array_equal(np.asarray(w),
                                np.arange(64*32, dtype=np.float32)
                                .reshape(64, 32))
            print("RESTORED", step, ok, w.sharding.spec)
        """)
        out = multidevice_run(restore, devices=4)
        assert "RESTORED 7 True" in out


def test_elastic_mesh_shapes():
    from repro.runtime.elastic import choose_mesh_shape
    assert choose_mesh_shape(256, prefer_model=16) == \
        ((16, 16), ("data", "model"))
    assert choose_mesh_shape(512, prefer_model=16, pod_size=256) == \
        ((2, 16, 16), ("pod", "data", "model"))
    assert choose_mesh_shape(6, prefer_model=4) == ((2, 3), ("data", "model"))
    assert choose_mesh_shape(7, prefer_model=4) == ((7, 1), ("data", "model"))
