"""Quickstart: on-the-fly data-free quantization with SQuant.

Quantizes a freshly-initialized reduced LM to 4-bit in milliseconds — no
data, no back-prop, no fine-tuning — and shows the CASE objective the
algorithm minimizes (per-kernel/per-channel absolute sums of error) dropping
versus plain rounding.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.pipeline import quantize_tree
from repro.core.squant import SQuantConfig, squant
from repro.models.model import build_model


def main():
    # --- single matrix: watch CASE collapse ------------------------------
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(512, 2048)).astype(np.float32))
    print("one 512x2048 matrix, 4-bit, group 128:")
    for tag, (ek, ec) in {"rounding (SQuant-E)": (False, False),
                          "SQuant-E&K": (True, False),
                          "SQuant-E&K&C": (True, True)}.items():
        qt, stats = squant(w, SQuantConfig(bits=4, group_size=128,
                                           enable_k=ek, enable_c=ec))
        d = np.asarray(qt.codes(), np.float64) - np.asarray(w) / \
            np.asarray(qt.scale)
        grp = np.abs(d.reshape(512, -1, 128).sum(-1))
        print(f"  {tag:22s} mean|kernel ASE|={grp.mean():6.3f}  "
              f"mean|channel ASE|={np.abs(d.sum(1)).mean():6.3f}  "
              f"flips K/C={int(stats['flips_k'])}/{int(stats['flips_c'])}")

    # --- whole model: sub-second, data-free, batched ---------------------
    # The batched pipeline groups same-shape layers into buckets, runs one
    # vmapped/Pallas dispatch per bucket, and syncs with the device once.
    # backend="auto" resolves TPU→pallas kernel, CPU→jnp reference.
    cfg = get_config("granite-3-8b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    quantize_tree(params, method="squant", bits=4, dequantize=False)  # jit
    t0 = time.perf_counter()
    qparams, report = quantize_tree(params, method="squant", bits=4,
                                    dequantize=False, backend="auto")
    dt = time.perf_counter() - t0
    print(f"\nwhole {cfg.name}: {report.summary()} "
          f"(wall {dt*1e3:.0f} ms, no data, no BP)")
    for b in report.buckets:
        print(f"  bucket {b.key}: {b.num_layers} layers, "
              f"{b.dispatch_millis:.2f} ms dispatch")
    from repro.quant.qtypes import QuantizedTensor
    qbytes = sum(
        leaf.nbytes() for leaf in jax.tree_util.tree_leaves(
            qparams, is_leaf=lambda x: isinstance(x, QuantizedTensor))
        if isinstance(leaf, QuantizedTensor))
    print(f"done — int4 codes + per-channel scales, {qbytes/1e6:.2f} MB "
          "of quantized kernels.")


if __name__ == "__main__":
    main()
