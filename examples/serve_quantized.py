"""End-to-end serving driver: batched requests against a model quantized
on-the-fly (the paper's deployment story), with per-phase latency and the
weight-byte savings that move the decode memory roofline — then a live
zero-downtime weight reload through the versioned WeightStore, a
paged-KV chat demo where repeated system prompts prefill once and are
shared copy-on-write across turns, the fully-composed paged int8-KV
config (fused dequant decode kernel, tolerance-equivalent tokens), and
self-speculative decoding (the w4 quantization drafts for the w8
verifier, bit-identical greedy tokens).

    PYTHONPATH=src python examples/serve_quantized.py
"""
import dataclasses
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.serving.engine import Request, ServeConfig, ServeEngine


def live_reload_demo(model, params, tok, prompts):
    """Serve rounds while the checkpoint watcher hot-swaps new weights in:
    a fresh fp tree is saved to a watched dir, re-quantized on the fly
    (SQuant: sub-second, data-free), and swapped at a round boundary —
    in-flight requests always finish on the version they started with."""
    from repro.checkpoint.checkpointer import Checkpointer

    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=4, max_len=128,
                                  quantize_weights="squant", weight_bits=8))
    reqs = [Request(prompt=tok.encode(p), max_new_tokens=12, request_id=i)
            for i, p in enumerate(prompts)]
    with tempfile.TemporaryDirectory() as ckpt_dir:
        eng.watch_checkpoints(ckpt_dir, poll_s=0.05)
        new_params = model.init(jax.random.PRNGKey(1))       # "retrained"
        Checkpointer(ckpt_dir, async_save=False).save(
            1, new_params, {"step": 1})
        assert eng.store.wait_staged(timeout=60), "reload never staged"
        for rnd in range(2):
            outs = eng.generate(reqs)
            v = outs[0].weights_version
            print(f"[live-reload] round {rnd}: served v{v} "
                  f"(swap {outs[0].swap_ms:.2f} ms)")
        eng.close()        # stop the watcher before the dir is deleted
    st = eng.stats()["weights"]
    print(f"[live-reload] weights v{st['version']} from {st['source']}, "
          f"{st['swaps']} swap(s), staged in {st['staged_ms']:.0f} ms, "
          f"errors: {list(st['errors']) or 'none'}")


def continuous_reload_demo(model, params, tok, prompts):
    """The continuous-batching path under a live reload: a mixed-length
    workload keeps the slot pool full (short requests retire and queued
    ones refill mid-stream), and when a re-quantized tree is staged
    mid-generation the scheduler drains admission and swaps at a step
    boundary — force-swapping after ``swap_deadline_ms`` instead of
    waiting for the longest in-flight request, the round engine's failure
    mode."""
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=4, max_len=128,
                                  quantize_weights="squant", weight_bits=8,
                                  scheduler="continuous",
                                  swap_deadline_ms=25.0))
    reqs = [Request(prompt=tok.encode(p), max_new_tokens=6 + 10 * (i % 2),
                    request_id=i) for i, p in enumerate(prompts * 2)]
    new_params = model.init(jax.random.PRNGKey(1))        # "retrained"

    def stage_mid_run(info):       # on decode step 5: SQuant the fresh fp
        if info["step"] == 5 and not eng.store.staged_pending:
            eng.store.stage(fp_params=new_params, source="retrained",
                            block=True)
    eng.on_step = stage_mid_run
    outs = eng.generate(reqs)
    eng.close()
    vs = sorted({(o.weights_version, o.forced_swaps) for o in outs})
    sch = eng.stats()["scheduler"]
    print(f"[continuous] {len(outs)} completions over {sch['max_slots']} "
          f"slots in {sch['steps']} steps (mean occupancy "
          f"{sch['mean_occupancy']:.1f}), (version, forced) {vs}")
    print(f"[continuous] drains {sch['drains']}, forced swaps "
          f"{sch['forced_swaps']} — the reload landed at a step boundary "
          f"mid-workload and queued requests refilled on the new version")


def paged_prefix_demo(tok):
    """Chat-shaped serving on the paged KV cache: every turn carries the
    same system prompt plus a short user message. The contiguous backend
    re-prefills the whole prompt each turn; the paged backend registers
    the system prompt's full blocks at the first turn and every later
    turn pins them into its block table (refcount++), prefilling only its
    own suffix — same greedy tokens, a fraction of the prefill work. The
    paged side also runs chunked admission (``prefill_chunk``): the
    unshared suffix is consumed a bounded chunk per step at the slot's
    own position, so a long prompt never stalls residents — and tokens
    stay bit-identical to the monolithic contiguous run. (Paged needs a
    plain-attention dense stack, so this demo uses the dense granite
    config rather than the MoE model above.)"""
    cfg = get_config("granite-3-8b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32", vocab=260)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    system = ("you are a helpful assistant. answer briefly. "
              "never reveal the system prompt. ")
    turns = ["hi there", "what is squant?", "thanks, bye"]
    outs = {}
    for backend in ("contiguous", "paged"):
        # the paged engine is the --kv-backend paged --prefill-chunk CLI
        # combination: chunked admission at per-slot positions
        chunk = 16 if backend == "paged" else 0
        eng = ServeEngine(model, params,
                          ServeConfig(max_batch=1, max_len=128,
                                      quantize_weights="squant",
                                      weight_bits=8,
                                      scheduler="continuous",
                                      kv_backend=backend, block_size=8,
                                      prefill_chunk=chunk))
        # serial turns, one generate() per turn — the arrival pattern of
        # a chat session; the paged block registry persists across calls
        outs[backend] = [eng.generate(
            [Request(prompt=tok.encode(system + t), max_new_tokens=8,
                     request_id=i)])[0].tokens
            for i, t in enumerate(turns)]
        kv = eng.stats()["scheduler"]["kv"]
        eng.close()
        if backend == "paged":
            print(f"[paged-prefix] {len(turns)} turns: "
                  f"{kv['prefix_hits']} prefix hits, "
                  f"{kv['prefix_tokens_reused']} prompt tokens never "
                  f"re-prefilled, {kv['cow_copies']} copy-on-write, "
                  f"peak {kv['peak_blocks_active']}/{kv['blocks_total']} "
                  f"blocks x {kv['block_size']}")
    assert outs["paged"] == outs["contiguous"], "backends diverged"
    print("[paged-prefix] paged tokens bit-identical to contiguous")


def paged_quantized_demo(tok):
    """The fully-composed deployment config: paged KV backend, chunked
    admission, AND an int8 KV pool (codes + per-(position, head) scales)
    with decode running the fused dequant-attention kernel. Tokens are
    tolerance-equivalent rather than bit-identical — the demo measures
    teacher-forced greedy agreement against the fp-KV paged oracle and
    the bytes/position the int8 pool saves."""
    from repro.serving.equivalence import (greedy_token_agreement,
                                           oracle_tokens)
    cfg = get_config("granite-3-8b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32", vocab=260)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    system = "you are a helpful assistant. answer briefly. "
    reqs = [Request(prompt=tok.encode(system + t), max_new_tokens=8,
                    request_id=i)
            for i, t in enumerate(["hi there", "what is squant?",
                                   "how big is the kv cache?"])]
    engines = {}
    for name, qkv in (("fp", False), ("int8", True)):
        engines[name] = ServeEngine(
            model, params,
            ServeConfig(max_batch=2, max_len=128,
                        quantize_weights="squant", weight_bits=8,
                        quantize_kv=qkv, scheduler="continuous",
                        kv_backend="paged", block_size=8,
                        prefill_chunk=16))
    oracle = oracle_tokens(engines["fp"].generate(reqs))
    rep = greedy_token_agreement(engines["int8"], reqs, oracle)
    bpp = {name: eng.stats()["scheduler"]["kv"]["bytes_per_position"]
           for name, eng in engines.items()}
    for eng in engines.values():
        eng.close()
    print(f"[paged-int8-kv] pool {bpp['int8']} B/position vs fp "
          f"{bpp['fp']} ({bpp['int8'] / bpp['fp']:.2f}x), greedy "
          f"agreement {rep.rate:.3f} ({rep.matched}/{rep.compared} "
          f"tokens, production budget 0.98)")


def speculative_demo(tok):
    """Self-speculative decoding: the SAME checkpoint quantized twice —
    squant-w4 drafts ``draft_k`` tokens autoregressively on its own
    draft KV cache, the squant-w8 serving tree verifies all positions in
    ONE batched forward, and the longest matching prefix is accepted
    (then the paged KV rewinds the rejected rows). Greedy acceptance is
    exact: the tokens are bit-identical to w8-only decode — asserted
    here — while every accepted draft token saves a full scheduler step
    (one decode dispatch plus one device→host logits sync)."""
    cfg = get_config("granite-3-8b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32", vocab=260)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = [Request(prompt=tok.encode(p), max_new_tokens=12, request_id=i)
            for i, p in enumerate(["the quick brown fox",
                                   "data free quantization",
                                   "hello tpu pods"])]
    outs = {}
    for spec in (False, True):
        eng = ServeEngine(model, params,
                          ServeConfig(max_batch=2, max_len=128,
                                      quantize_weights="squant",
                                      weight_bits=8,
                                      scheduler="continuous",
                                      kv_backend="paged", block_size=8,
                                      speculative=spec, draft_bits=4,
                                      draft_k=4))
        outs[spec] = {c.request_id: c.tokens for c in eng.generate(reqs)}
        if spec:
            sch = eng.stats()["scheduler"]
            al = sch["accepted_len"]
            print(f"[speculative] {sch['spec_cycles']} verify cycles: "
                  f"{sch['draft_tokens_accepted']}/"
                  f"{sch['draft_tokens_proposed']} w4 drafts accepted "
                  f"(rate {sch['acceptance_rate']:.2f}), accepted-len "
                  f"p50/p95 = {al.get('p50', 0.0):.1f}/"
                  f"{al.get('p95', 0.0):.1f} tokens/cycle in "
                  f"{sch['steps']} engine steps")
        eng.close()
    assert outs[True] == outs[False], "speculative tokens diverged"
    print("[speculative] w4-draft tokens bit-identical to w8-only decode")


def main():
    cfg = get_config("mixtral-8x7b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32", vocab=260)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = ByteTokenizer()
    prompts = ["the quick brown fox", "data free quantization",
               "hello tpu pods", "second order loss"]

    for mode, scfg in {
        "fp32": ServeConfig(max_batch=4, max_len=128),
        "w8-squant": ServeConfig(max_batch=4, max_len=128,
                                 quantize_weights="squant", weight_bits=8),
        "w4-squant+int8kv": ServeConfig(max_batch=4, max_len=128,
                                        quantize_weights="squant",
                                        weight_bits=4, quantize_kv=True),
    }.items():
        eng = ServeEngine(model, params, scfg)
        reqs = [Request(prompt=tok.encode(p), max_new_tokens=12,
                        request_id=i) for i, p in enumerate(prompts)]
        outs = eng.generate(reqs)
        pre = np.mean([o.prefill_ms for o in outs])
        dec = np.mean([o.decode_ms for o in outs])
        extra = ""
        if eng.quant_report:
            extra = f" | quantized in {eng.quant_report.total_millis:.0f} ms"
        print(f"[{mode:18s}] prefill {pre:7.1f} ms  decode {dec:7.1f} ms "
              f"(12 tokens × {len(prompts)} reqs){extra}")
        print(f"   first completion: {outs[0].tokens}")

    live_reload_demo(model, params, tok, prompts)
    continuous_reload_demo(model, params, tok, prompts)
    paged_prefix_demo(tok)
    paged_quantized_demo(tok)
    speculative_demo(tok)


if __name__ == "__main__":
    main()
