"""End-to-end serving driver: batched requests against a model quantized
on-the-fly (the paper's deployment story), with per-phase latency and the
weight-byte savings that move the decode memory roofline.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.serving.engine import Request, ServeConfig, ServeEngine


def main():
    cfg = get_config("mixtral-8x7b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32", vocab=260)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = ByteTokenizer()
    prompts = ["the quick brown fox", "data free quantization",
               "hello tpu pods", "second order loss"]

    for mode, scfg in {
        "fp32": ServeConfig(max_batch=4, max_len=128),
        "w8-squant": ServeConfig(max_batch=4, max_len=128,
                                 quantize_weights="squant", weight_bits=8),
        "w4-squant+int8kv": ServeConfig(max_batch=4, max_len=128,
                                        quantize_weights="squant",
                                        weight_bits=4, quantize_kv=True),
    }.items():
        eng = ServeEngine(model, params, scfg)
        reqs = [Request(prompt=tok.encode(p), max_new_tokens=12,
                        request_id=i) for i, p in enumerate(prompts)]
        outs = eng.generate(reqs)
        pre = np.mean([o.prefill_ms for o in outs])
        dec = np.mean([o.decode_ms for o in outs])
        extra = ""
        if eng.quant_report:
            extra = f" | quantized in {eng.quant_report.total_millis:.0f} ms"
        print(f"[{mode:18s}] prefill {pre:7.1f} ms  decode {dec:7.1f} ms "
              f"(12 tokens × {len(prompts)} reqs){extra}")
        print(f"   first completion: {outs[0].tokens}")


if __name__ == "__main__":
    main()
