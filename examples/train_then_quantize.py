"""End-to-end: train a small LM for a few hundred steps (fault-tolerant
trainer, checkpoints), then quantize the checkpoint data-free with SQuant
and every baseline, comparing held-out cross-entropy.

    PYTHONPATH=src python examples/train_then_quantize.py [--steps 300]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.pipeline import quantize_tree
from repro.data.synthetic import markov_batches
from repro.models.model import build_model
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--size", default="small", choices=["small", "100m"])
    ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    cfg = get_config("granite-3-8b", reduced=True)
    if args.size == "100m":
        cfg = dataclasses.replace(cfg, n_layers=12, d_model=768, n_heads=12,
                                  n_kv_heads=4, head_dim=64, d_ff=2048,
                                  vocab=32_000, dtype="float32")
    else:
        cfg = dataclasses.replace(cfg, dtype="float32", d_model=128,
                                  n_heads=8, n_kv_heads=4, head_dim=16,
                                  d_ff=256, vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"[example] {cfg.name}: {n_params/1e6:.1f} M params")

    trainer = Trainer(model, AdamWConfig(lr=3e-3, warmup_steps=20,
                                         decay_steps=args.steps),
                      TrainerConfig(total_steps=args.steps,
                                    checkpoint_every=100,
                                    checkpoint_dir=args.ckpt,
                                    log_every=25))
    it = (jax.tree_util.tree_map(jnp.asarray, b)
          for b in markov_batches(16, 64, cfg.vocab, seed=7))
    params, _, info = trainer.run(params, it)

    evals = [jax.tree_util.tree_map(jnp.asarray, b) for b, _ in
             zip(markov_batches(16, 64, cfg.vocab, seed=7, start=100_000),
                 range(4))]

    @jax.jit
    def xent(p, b):
        return model.train_loss(p, b)[1]["xent"]

    def ev(p):
        return float(np.mean([float(xent(p, b)) for b in evals]))

    base = ev(params)
    print(f"\n[example] trained fp32 held-out xent {base:.4f}")
    print(f"{'method':12s} {'w8':>8s} {'w6':>8s} {'w4':>8s} {'w3':>8s}")
    for method in ("rtn", "squant_ek", "squant"):
        row = []
        for bits in (8, 6, 4, 3):
            q, rep = quantize_tree(params, method=method, bits=bits,
                                   group_size=32, dequantize=True)
            row.append(ev(q))
        print(f"{method:12s} " + " ".join(f"{x:8.4f}" for x in row) +
              f"   ({rep.total_millis:.0f} ms quant)")
    print(f"(fp32 reference {base:.4f}; lower is better — SQuant should "
          "track fp32 longest as bits shrink)")


if __name__ == "__main__":
    main()
