"""Serving-scheduler benchmark: round vs continuous batching.

Two experiments on a mixed-length workload (short requests interleaved
with a few long ones — the shape that static rounds serve worst, because
every request in a round waits for the round's longest):

* **throughput** — end-to-end useful tokens/s for the same workload under
  ``scheduler="round"`` vs ``scheduler="continuous"`` (acceptance:
  continuous ≥ 1.2x);
* **reload dip** — a weight version is staged mid-run (a *native* serving
  tree, so staging itself is ~free and the measurement isolates the
  *scheduling* cost of landing a reload, complementing
  ``bench_reload.py``'s staging-contention dip). Per-step useful-token
  rates around the stage→swap window give each engine's decode dip and
  swap lag: the round engine can only swap after its longest in-flight
  request finishes, the continuous engine drains admission and force-swaps
  after ``swap_deadline_ms``;
* **prefill tail** — resident slots decode long budgets while long-prompt
  requests are admitted mid-flight. Monolithic admission stalls every
  resident for the full prefill (the p99 decode step-time spike);
  ``prefill_chunk`` consumes the same prompt a bounded chunk per step.
  Both paths pad the long prompts to the same clock, so their greedy
  tokens must be bit-identical (verified) — the chunked path buys its
  p50/p95/p99 step-time profile for free.
* **shared prefix** — a chat-shaped serial-turn workload: every turn
  carries the same long system prompt plus a short distinct user suffix.
  The contiguous backend re-prefills the full prompt every turn; the
  paged backend (``kv_backend="paged"``) finds the system prompt in its
  block registry and prefills only the suffix. Greedy tokens must be
  bit-identical between the backends (verified); reported are the
  throughput ratio (acceptance: paged ≥ 1.3x), the prefix hit rate, and
  resident KV bytes per context token.
* **paged chunked admission** — the shared-prefix shape under mid-flight
  admission: residents decode while turns carrying a long registered
  prefix plus a long unshared suffix admit into the cycle slot. On the
  paged backend every pending's target is its own prompt length, so
  chunked admission works at any chunk size mid-flight and its greedy
  tokens must be bit-identical to monolithic paged admission (verified);
  the chunked/monolithic p99 decode step-time ratio is held to the same
  bar as the contiguous chunked-prefill experiment.
* **kv bytes** — 32-slot paged decode with an fp32 KV pool vs an int8
  one (``quantize_kv=True``, the fused dequant-attention kernel path).
  Decode at production slot counts is roofline-bound on KV-cache HBM
  bytes per token; the int8 pool moves ``2*D + 8`` bytes per (position,
  kv-head, layer) instead of ``2*D*itemsize`` (acceptance: ≤ 0.6x fp).
  Quantized-KV tokens are NOT bit-identical to fp — the tolerance-
  equivalence harness measures teacher-forced greedy-token agreement vs
  the fp paged oracle instead (hard floor: ≥ 0.98).

* **speculative** — self-speculative decoding on the paged backend: a
  lower-bit squant quantization of the checkpoint drafts ``draft_k``-token
  runs per slot, the squant-w8 serving tree verifies all positions in one
  batched forward, the longest matching prefix is accepted. Greedy
  acceptance makes the output tokens bit-identical to w8-only decode
  (hard-asserted for every draft bit-width measured); reported are the
  w4..w7 acceptance-rate ladder, the p50/p95 accepted run length, and
  the headline throughput/steps ratio vs w8-only (acceptance: throughput
  ≥ 1.0x — every accepted draft saves a full scheduler step's dispatch +
  host logits sync).

Writes ``BENCH_serving.json`` (or ``--smoke`` scale for the CI bench
gate, compared against the committed baseline by
``scripts/check_bench.py``).
"""
from __future__ import annotations

import dataclasses
import gc
import json
import sys
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.serving.engine import Request, ServeConfig, ServeEngine

DIP_WINDOW = 6          # steps per useful-rate window


def _swap_deadline_ms(smoke: bool) -> float:
    """Continuous force-swap deadline for the reload bench: a handful of
    decode steps at each scale (tiny-model steps are ~4x cheaper)."""
    return 1.5 if smoke else 8.0


def _model(smoke: bool):
    cfg = get_config("granite-3-8b", reduced=True)
    over = dict(dtype="float32")
    if smoke:
        over.update(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                    head_dim=16, d_ff=64, vocab=256)
    cfg = dataclasses.replace(cfg, **over)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def mixed_workload(smoke: bool) -> List[Request]:
    """Mostly-short requests with one long request per round-sized chunk,
    so every static round is dominated by its longest member."""
    n, slots = (10, 4) if smoke else (24, 8)
    long_budget, short_budgets = (24, (3, 4, 6)) if smoke \
        else (64, (6, 8, 10, 12))
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n):
        budget = long_budget if i % slots == 0 \
            else short_budgets[i % len(short_budgets)]
        plen = int(rng.integers(3, 11))
        prompt = [int(t) for t in rng.integers(1, 60, size=plen)]
        reqs.append(Request(prompt=prompt, max_new_tokens=budget,
                            request_id=i))
    return reqs


def _serve_cfg(scheduler: str, smoke: bool, **over) -> ServeConfig:
    slots = 4 if smoke else 8
    return ServeConfig(max_batch=slots, max_len=96 if smoke else 192,
                       scheduler=scheduler, **over)


def bench_throughput(smoke: bool = False, repeats: int = 3,
                     report=print) -> Dict:
    model, params = _model(smoke)
    reqs = mixed_workload(smoke)
    total_tokens = sum(r.max_new_tokens for r in reqs)
    out: Dict = {"requests": len(reqs), "useful_tokens": total_tokens}
    for scheduler in ("round", "continuous"):
        eng = ServeEngine(model, params, _serve_cfg(scheduler, smoke))
        eng.generate(reqs)                       # warm every jit shape
        steps0 = eng.stats()["scheduler"]["steps"]
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            outs = eng.generate(reqs)
            best = min(best, time.perf_counter() - t0)
        assert sum(len(o.tokens) for o in outs) == total_tokens
        steps = eng.stats()["scheduler"]["steps"] - steps0
        eng.close()
        out[scheduler] = {"tok_s": total_tokens / best,
                          "wall_ms": best * 1e3,
                          "steps_per_run": steps // repeats}
        report(f"[serving] {scheduler:10s}: {out[scheduler]['tok_s']:7.0f} "
               f"tok/s ({out[scheduler]['wall_ms']:.0f} ms, "
               f"{out[scheduler]['steps_per_run']} steps)")
    out["ratio"] = out["continuous"]["tok_s"] / out["round"]["tok_s"]
    report(f"[serving] continuous/round throughput ratio: "
           f"{out['ratio']:.2f}x")
    return out


def _dip_metrics(steps: List[dict], stage_idx: int,
                 w: int = DIP_WINDOW) -> Dict:
    """Windowed useful-token rates around the stage→swap interval."""
    rec = [e["recorded"] for e in steps]
    v0 = steps[0]["version"]
    swap_idx = next((i for i, e in enumerate(steps) if e["version"] > v0),
                    None)
    if swap_idx is None:
        raise RuntimeError(
            f"swap never observed in the {len(steps)}-step log (staged at "
            f"step {stage_idx}) — stage earlier or grow the workload")
    steady = sum(rec[max(0, stage_idx - w):stage_idx]) \
        / min(w, max(1, stage_idx))
    hi = min(len(rec) - w, swap_idx + w)
    rates = [sum(rec[i:i + w]) / w
             for i in range(stage_idx, max(stage_idx + 1, hi))]
    min_rate = min(rates)
    return {"steady_rate": steady, "min_rate": min_rate,
            "dip_pct": 100.0 * (1.0 - min_rate / steady),
            "swap_lag_steps": swap_idx - stage_idx}


def bench_reload_dip(smoke: bool = False, report=print) -> Dict:
    model, params = _model(smoke)
    params2 = model.init(jax.random.PRNGKey(1))
    reqs = mixed_workload(smoke)
    stage_step = 5 if smoke else 12
    deadline = _swap_deadline_ms(smoke)
    out: Dict = {"stage_step": stage_step, "swap_deadline_ms": deadline}
    for scheduler in ("round", "continuous"):
        eng = ServeEngine(model, params,
                          _serve_cfg(scheduler, smoke,
                                     swap_deadline_ms=deadline))
        eng.generate(reqs)                       # warm every jit shape
        marks: Dict = {}
        orig_acquire = eng.store.acquire

        def acquire(orig=orig_acquire, marks=marks):
            ver, sms = orig()
            if ver.version >= 2 and "t_swap" not in marks:
                marks["t_swap"] = time.perf_counter()
            return ver, sms

        eng.store.acquire = acquire

        def hook(info, eng=eng, marks=marks):
            if info["step"] == marks["stage_at"] \
                    and "t_stage" not in marks:
                # native serving tree: staging is ~free, isolating the
                # *scheduling* dip from bench_reload's contention dip
                eng.store.stage(serving_params=params2, source="bench",
                                block=True)
                marks["t_stage"] = time.perf_counter()

        eng.on_step = hook
        marks["stage_at"] = eng.scheduler.steps_total + stage_step
        eng.scheduler.step_log = steps = []
        outs = eng.generate(reqs)
        assert sum(len(o.tokens) for o in outs) \
            == sum(r.max_new_tokens for r in reqs)
        m = _dip_metrics(steps, stage_step)
        m["swap_lag_ms"] = (marks["t_swap"] - marks["t_stage"]) * 1e3
        if scheduler == "continuous":
            m["forced_swaps"] = eng.stats()["scheduler"]["forced_swaps"]
        eng.close()
        out[scheduler] = m
        report(f"[serving] reload {scheduler:10s}: steady "
               f"{m['steady_rate']:.1f} tok/step → min {m['min_rate']:.1f} "
               f"(dip {m['dip_pct']:.0f}%), swap lag "
               f"{m['swap_lag_steps']} steps / {m['swap_lag_ms']:.1f} ms")
    out["dip_advantage_pct"] = \
        out["round"]["dip_pct"] - out["continuous"]["dip_pct"]
    report(f"[serving] continuous reload dip is "
           f"{out['dip_advantage_pct']:.0f} pts smaller than round")
    return out


def _tail_model():
    """A wider LM for the prefill-tail experiment: at toy widths both
    prefill and decode are pure dispatch overhead, so the admission spike
    chunking bounds would be invisible. This width makes a long-prompt
    prefill FLOPs-bound (~2-4x a decode step) while a single chunk stays
    well under one."""
    cfg = get_config("granite-3-8b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32", d_model=384, d_ff=1024,
                              n_heads=4, n_kv_heads=2, head_dim=64,
                              vocab=512)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def long_prompt_workload(smoke: bool):
    """Three background residents with 240-token prompts (the pool clock
    starts deep, so every later admission pays a long prefill) decode while
    a cycle slot serves one short request and then a sequence of ever-longer
    long-prompt requests admitted mid-flight. Each long prompt length is
    derived from the scheduler's own catch-up recurrence, so the chunked
    path's committed completion clock lands exactly on the prompt length —
    which is also the first clock the monolithic path can admit it at:
    identical padding in both paths, hence bit-identical greedy tokens.
    Admission spikes are ~4% of steps, putting p99 squarely on them.
    Fixed-size at every scale (like ``bench_reload``'s latency table): the
    spike is a function of prompt length, so shrinking it would measure
    nothing."""
    del smoke
    max_len, chunk = 384, 16
    wave_clock, cycle_budget, long_budget = 240, 4, 6
    # the scheduler's mid-flight commit: admitted at clock C0, a pending
    # needs s = ceil((C0-1)/(chunk-1)) steps to catch the moving clock, so
    # a prompt of exactly C0+s-1 tokens completes at its own length
    long_lens = []
    c0 = wave_clock + cycle_budget
    while True:
        ln = c0 + max(1, -(-(c0 - 1) // (chunk - 1))) - 1
        if ln + long_budget > max_len:
            break
        long_lens.append(ln)
        c0 = ln + long_budget
    rng = np.random.default_rng(7)
    reqs = [Request(prompt=[int(t) for t in
                            rng.integers(1, 500, size=wave_clock)],
                    max_new_tokens=long_lens[-1] + long_budget - wave_clock,
                    request_id=i)
            for i in range(3)]
    reqs.append(Request(prompt=[int(t) for t in rng.integers(1, 500,
                                                             size=3)],
                        max_new_tokens=cycle_budget, request_id=3))
    for j, ln in enumerate(long_lens):
        reqs.append(Request(
            prompt=[int(t) for t in rng.integers(1, 500, size=ln)],
            max_new_tokens=long_budget, request_id=4 + j))
    return reqs, max_len, chunk


def bench_prefill_tail(smoke: bool = False, repeats: int = 6,
                       report=print) -> Dict:
    # always the FLOPs-bound width and the fixed-size workload — `smoke`
    # is accepted for signature parity with the other experiments but
    # changes nothing (a shrunken spike would measure nothing)
    model, params = _tail_model()
    reqs, max_len, chunk = long_prompt_workload(smoke)
    out: Dict = {"requests": len(reqs), "prefill_chunk": chunk,
                 "long_prompt_lens": [len(r.prompt) for r in reqs[4:]]}
    tokens: Dict[str, List] = {}
    clocks: Dict[str, List[int]] = {}
    for label, c in (("monolithic", 0), ("chunked", chunk)):
        eng = ServeEngine(model, params,
                          ServeConfig(max_batch=4, max_len=max_len,
                                      scheduler="continuous",
                                      prefill_chunk=c))
        eng.generate(reqs)                   # warm every jit shape
        # the schedule is deterministic, so repeated runs visit the same
        # per-step work: the elementwise min strips container stalls
        # (thread-pool hiccups) that would otherwise own the tail. GC is
        # paused outright — its pauses trigger at allocation counts, which
        # recur at the SAME step every repeat, so min-of-N can't strip them
        per_run = []
        gc.collect()
        gc.disable()
        try:
            for _ in range(repeats):
                eng.scheduler.step_log = steps = []
                outs = eng.generate(reqs)
                per_run.append([e["step_ms"] for e in steps])
        finally:
            gc.enable()
        assert len({len(r) for r in per_run}) == 1
        ms = np.asarray(per_run, np.float64).min(axis=0)
        tokens[label] = [o.tokens for o in outs]
        clocks[label] = [e["clock"] for e in eng.scheduler.admission_log
                         if e["request_id"] >= 4][-len(reqs[4:]):]
        out[label] = {
            "steps": int(ms.size),
            "p50_ms": float(np.percentile(ms, 50)),
            "p95_ms": float(np.percentile(ms, 95)),
            "p99_ms": float(np.percentile(ms, 99)),
            "max_ms": float(ms.max()),
        }
        if c:
            out[label]["chunk_steps"] = \
                eng.stats()["scheduler"]["chunk_steps"] // (repeats + 1)
        eng.close()
        m = out[label]
        report(f"[serving] prefill-tail {label:10s}: step-time p50 "
               f"{m['p50_ms']:6.2f} / p95 {m['p95_ms']:6.2f} / p99 "
               f"{m['p99_ms']:6.2f} / max {m['max_ms']:6.2f} ms "
               f"({m['steps']} steps)")
    out["tokens_identical"] = tokens["monolithic"] == tokens["chunked"]
    out["admission_clocks_identical"] = \
        clocks["monolithic"] == clocks["chunked"]
    if not out["tokens_identical"]:
        raise RuntimeError(
            "chunked prefill diverged from the monolithic path: greedy "
            f"tokens differ (admission clocks {clocks['monolithic']} vs "
            f"{clocks['chunked']}) — the equivalence guarantee is broken")
    out["p99_ratio"] = out["chunked"]["p99_ms"] / out["monolithic"]["p99_ms"]
    report(f"[serving] prefill-tail chunked/monolithic p99 ratio: "
           f"{out['p99_ratio']:.2f}x (tokens bit-identical)")
    return out


def shared_prefix_workload(smoke: bool):
    """Serial chat turns: one long shared system prompt + a short distinct
    user suffix per turn. Serving this contiguously re-prefills the system
    prompt every turn; the paged backend prefills it once and reuses its
    registered blocks for every later turn."""
    del smoke
    sys_len, turns, sfx, new = 512, 8, 8, 8
    rng = np.random.default_rng(11)
    sys_prompt = [int(t) for t in rng.integers(1, 200, size=sys_len)]
    reqs = [Request(prompt=sys_prompt
                    + [int(t) for t in rng.integers(1, 200, size=sfx)],
                    max_new_tokens=new, request_id=i)
            for i in range(turns)]
    return reqs, sys_len


def bench_shared_prefix(smoke: bool = False, repeats: int = 3,
                        report=print) -> Dict:
    """Paged-vs-contiguous on the shared-prefix workload. Both backends run
    the continuous scheduler with one slot and serve the turns serially
    (one ``generate`` per turn, the arrival pattern of a chat session), so
    each turn's prompt sits at positions ``0..L-1`` in both backends and
    greedy tokens must be bit-identical. Fixed-size at every scale (like
    ``bench_prefill_tail``), on the FLOPs-bound ``_tail_model`` width: the
    experiment measures prefill *avoidance*, and at toy widths a 500-token
    prefill is pure dispatch overhead — shrinking it would measure the
    paged backend's extra gather/scatter dispatches instead."""
    model, params = _tail_model()
    reqs, sys_len = shared_prefix_workload(smoke)
    max_len, bs = 768, 16
    ctx_len = len(reqs[0].prompt) + reqs[0].max_new_tokens
    new_tokens = sum(r.max_new_tokens for r in reqs)
    out: Dict = {"turns": len(reqs), "system_prompt_len": sys_len,
                 "context_len": ctx_len, "block_size": bs}
    tokens: Dict[str, List] = {}
    for backend in ("contiguous", "paged"):
        over = {} if backend == "contiguous" else dict(
            kv_backend="paged", block_size=bs,
            kv_blocks=2 * (max_len // bs) + 1)
        eng = ServeEngine(model, params,
                          ServeConfig(max_batch=1, max_len=max_len,
                                      scheduler="continuous", **over))

        def turns(eng=eng, reqs=reqs):
            return [eng.generate([r])[0] for r in reqs]

        turns()                 # warm every jit shape + the block registry
        kv0 = eng.scheduler.stats()["kv"]
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            outs = turns()
            best = min(best, time.perf_counter() - t0)
        kv = eng.scheduler.stats()["kv"]
        eng.close()
        tokens[backend] = [o.tokens for o in outs]
        m = {"tok_s": new_tokens / best, "wall_ms": best * 1e3,
             "turn_ms": best * 1e3 / len(reqs)}
        if backend == "paged":
            timed = repeats * len(reqs)
            m["prefix_hit_rate"] = \
                (kv["prefix_hits"] - kv0["prefix_hits"]) / timed
            m["prefix_tokens_reused_per_turn"] = \
                (kv["prefix_tokens_reused"]
                 - kv0["prefix_tokens_reused"]) / timed
            m["cow_copies"] = kv["cow_copies"]
            per_pos = kv["block_bytes"] // bs
            m["kv_bytes_per_ctx_token"] = \
                kv["peak_blocks_active"] * kv["block_bytes"] / ctx_len
        else:
            # one contiguous slot always holds max_len positions
            per_pos = None
            m["kv_bytes_per_ctx_token"] = None
        out[backend] = m
        if per_pos is not None:
            out["contiguous"]["kv_bytes_per_ctx_token"] = \
                per_pos * max_len / ctx_len
        report(f"[serving] shared-prefix {backend:10s}: "
               f"{m['tok_s']:7.0f} tok/s ({m['turn_ms']:.1f} ms/turn)")
    out["tokens_identical"] = tokens["paged"] == tokens["contiguous"]
    if not out["tokens_identical"]:
        raise RuntimeError(
            "paged backend diverged from contiguous on the shared-prefix "
            "workload: greedy tokens differ — the bit-identity guarantee "
            "is broken")
    out["ratio"] = out["paged"]["tok_s"] / out["contiguous"]["tok_s"]
    report(f"[serving] shared-prefix paged/contiguous ratio: "
           f"{out['ratio']:.2f}x (hit rate "
           f"{out['paged']['prefix_hit_rate']:.2f}, "
           f"{out['paged']['prefix_tokens_reused_per_turn']:.0f} prefix "
           f"tokens reused/turn, tokens bit-identical)")
    return out


def paged_chunked_workload(sets: int):
    """Chat-shaped mid-flight admissions on the paged backend: three
    residents decode long budgets while a cycle slot serves a sequence of
    turns that all carry the same 256-token system prefix plus a long
    distinct suffix. Once the prefix is registered, every admission pins
    its blocks and prefills only the ~288-token suffix — monolithically
    that suffix is the p99 decode step-time spike; chunked it is a bounded
    chunk per step. Suffixes are distinct per request *set* (the registry
    would otherwise absorb them after one pass and leave nothing to
    prefill) but share lengths, so every set visits the same per-step work
    and the elementwise min across sets is valid."""
    max_len, bs, chunk = 576, 16, 16
    pfx, sfx, turn_budget, turns = 256, 288, 8, 6
    rng = np.random.default_rng(13)
    prefix = [int(t) for t in rng.integers(1, 500, size=pfx)]
    residents = [Request(prompt=[int(t) for t in
                                 rng.integers(1, 500, size=64)],
                         max_new_tokens=160, request_id=i)
                 for i in range(3)]
    reqs_by_set = []
    for s in range(sets):
        reqs_by_set.append(residents + [
            Request(prompt=prefix + [int(t) for t in
                                     rng.integers(1, 500, size=sfx)],
                    max_new_tokens=turn_budget,
                    request_id=100 * s + 10 + j)
            for j in range(turns)])
    return reqs_by_set, dict(max_len=max_len, block_size=bs, chunk=chunk,
                             prefix_len=pfx, suffix_len=sfx, turns=turns)


def bench_paged_chunked(smoke: bool = False, repeats: int = 4,
                        report=print) -> Dict:
    """Paged chunked admission vs paged monolithic admission on the
    long-shared-prefix workload. Under the paged backend every pending's
    completion target is its own prompt length (no catch-up recurrence),
    so tokens are position-deterministic and must stay bit-identical for
    every chunk split (verified). Fixed-size at every scale on the
    FLOPs-bound ``_tail_model`` width, for the same reason as
    ``bench_prefill_tail``: the admission spike is a function of the
    unshared-suffix length, and shrinking it would measure nothing."""
    del smoke
    model, params = _tail_model()
    sets = repeats + 2               # 2 warm sets + `repeats` timed sets
    reqs_by_set, wl = paged_chunked_workload(sets)
    out: Dict = {"turns": wl["turns"], "system_prefix_len": wl["prefix_len"],
                 "suffix_len": wl["suffix_len"],
                 "prefill_chunk": wl["chunk"], "block_size": wl["block_size"]}
    tokens: Dict[str, List] = {}
    for label, c in (("monolithic", 0), ("chunked", wl["chunk"])):
        eng = ServeEngine(model, params,
                          ServeConfig(max_batch=4, max_len=wl["max_len"],
                                      scheduler="continuous",
                                      kv_backend="paged",
                                      block_size=wl["block_size"],
                                      kv_blocks=800, prefill_chunk=c))
        # set 0 fills the block registry (first-touch full prefills); set 1
        # warms the steady-state jit shapes (admissions now hit the
        # registered prefix, so the suffix-width forwards appear here)
        for s in range(2):
            eng.generate(reqs_by_set[s])
        kv0 = eng.scheduler.stats()["kv"]
        adm0, chunk0 = eng.scheduler.admitted, eng.scheduler.chunk_steps
        per_run: List[List[float]] = []
        toks: List[List] = []
        gc.collect()
        gc.disable()
        try:
            for s in range(2, sets):
                eng.scheduler.step_log = steps = []
                outs = eng.generate(reqs_by_set[s])
                per_run.append([e["step_ms"] for e in steps])
                toks.append([o.tokens for o in outs])
        finally:
            gc.enable()
        assert len({len(r) for r in per_run}) == 1
        ms = np.asarray(per_run, np.float64).min(axis=0)
        kv = eng.scheduler.stats()["kv"]
        timed_admits = eng.scheduler.admitted - adm0
        m = {
            "steps": int(ms.size),
            "p50_ms": float(np.percentile(ms, 50)),
            "p95_ms": float(np.percentile(ms, 95)),
            "p99_ms": float(np.percentile(ms, 99)),
            "max_ms": float(ms.max()),
            "prefix_hit_rate":
                (kv["prefix_hits"] - kv0["prefix_hits"]) / timed_admits,
        }
        if c:
            m["chunk_steps_per_set"] = \
                (eng.scheduler.chunk_steps - chunk0) // repeats
        eng.close()
        tokens[label] = toks
        out[label] = m
        report(f"[serving] paged-chunked {label:10s}: step-time p50 "
               f"{m['p50_ms']:6.2f} / p95 {m['p95_ms']:6.2f} / p99 "
               f"{m['p99_ms']:6.2f} / max {m['max_ms']:6.2f} ms "
               f"({m['steps']} steps, hit rate "
               f"{m['prefix_hit_rate']:.2f})")
    out["tokens_identical"] = tokens["monolithic"] == tokens["chunked"]
    if not out["tokens_identical"]:
        raise RuntimeError(
            "paged chunked admission diverged from the monolithic paged "
            "path: greedy tokens differ — the bit-identity guarantee is "
            "broken")
    out["p99_ratio"] = out["chunked"]["p99_ms"] / out["monolithic"]["p99_ms"]
    report(f"[serving] paged-chunked chunked/monolithic p99 ratio: "
           f"{out['p99_ratio']:.2f}x (tokens bit-identical)")
    return out


def kv_bytes_workload():
    """32 slots of distinct mid-length prompts decoding in lockstep —
    the all-residents-decoding shape where KV-cache HBM traffic owns the
    roofline. Fixed-size at every scale: the bytes-per-position ratio is
    dtype arithmetic and the agreement rate needs enough compared tokens
    (32 slots x 24 tokens = 768) for a per-mille flip rate to resolve."""
    slots, plen, new = 32, 48, 24
    rng = np.random.default_rng(21)
    reqs = [Request(prompt=[int(t) for t in rng.integers(1, 500, size=plen)],
                    max_new_tokens=new, request_id=i)
            for i in range(slots)]
    return reqs, dict(max_len=128, block_size=16, slots=slots,
                      prompt_len=plen, new_tokens=new)


def bench_kv_bytes(smoke: bool = False, repeats: int = 3,
                   report=print) -> Dict:
    """fp32 vs int8 KV pools on the 32-slot paged decode workload.

    Reports device bytes per cached position (all layers, from the live
    pool), the KV bytes a decode step reads per token (bytes/position x
    mean context length — identical contexts in both runs, so the ratio
    is exactly the dtype ratio), throughput, and the teacher-forced
    greedy-token agreement of the int8 config vs the fp oracle
    (``repro.serving.equivalence``; both engines are deterministic greedy,
    so the rate is reproducible). ``smoke`` is accepted for signature
    parity but changes nothing — see :func:`kv_bytes_workload`."""
    del smoke
    from repro.serving.equivalence import (greedy_token_agreement,
                                           oracle_tokens)
    model, params = _tail_model()
    reqs, wl = kv_bytes_workload()
    new_tokens = sum(r.max_new_tokens for r in reqs)
    # context length while decoding token t is prompt_len + t
    mean_ctx = wl["prompt_len"] + (wl["new_tokens"] - 1) / 2
    out: Dict = dict(wl, mean_context_len=mean_ctx)
    engines: Dict[str, ServeEngine] = {}
    oracle = None
    for label, quant in (("fp", False), ("int8", True)):
        eng = ServeEngine(model, params,
                          ServeConfig(max_batch=wl["slots"],
                                      max_len=wl["max_len"],
                                      max_slots=wl["slots"],
                                      scheduler="continuous",
                                      kv_backend="paged",
                                      block_size=wl["block_size"],
                                      quantize_kv=quant))
        outs = eng.generate(reqs)                # warm every jit shape
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            outs = eng.generate(reqs)
            best = min(best, time.perf_counter() - t0)
        if label == "fp":
            oracle = oracle_tokens(outs)
        kv = eng.scheduler.stats()["kv"]
        engines[label] = eng
        m = {"tok_s": new_tokens / best, "wall_ms": best * 1e3,
             "bytes_per_position": kv["bytes_per_position"],
             "kv_bytes_per_token": kv["bytes_per_position"] * mean_ctx,
             "pool_bytes": kv["pool_bytes"]}
        out[label] = m
        report(f"[serving] kv-bytes {label:5s}: {m['tok_s']:7.0f} tok/s, "
               f"{m['bytes_per_position']} B/position "
               f"({m['kv_bytes_per_token'] / 1024:.0f} KiB read/token, "
               f"pool {m['pool_bytes'] / 2**20:.1f} MiB)")
    agreement = greedy_token_agreement(engines["int8"], reqs, oracle)
    for eng in engines.values():
        eng.close()
    out["agreement"] = agreement.rate
    out["agreement_compared"] = agreement.compared
    out["bytes_ratio"] = out["int8"]["bytes_per_position"] \
        / out["fp"]["bytes_per_position"]
    out["throughput_ratio"] = out["int8"]["tok_s"] / out["fp"]["tok_s"]
    report(f"[serving] kv-bytes int8/fp: bytes {out['bytes_ratio']:.2f}x, "
           f"throughput {out['throughput_ratio']:.2f}x, greedy agreement "
           f"{out['agreement']:.4f} over {out['agreement_compared']} tokens")
    return out


# one ladder row per newly-ungated architecture feature, on reduced
# registry configs shrunk to 2 layers (agreement is a property of the
# mixer math, not the width — tiny widths keep the ladder cheap enough
# for the CI bench gate). The jamba row isolates the mamba mixer
# (moe=None, one mamba + one attention block); the mixtral row measures
# the composed sliding_window x moe stack.
CHUNKED_ARCH_ROWS = (
    ("sliding_window", "granite-3-8b", dict(n_layers=2, window=8)),
    ("mla", "minicpm3-4b", dict(n_layers=2)),
    ("moe", "moonshot-v1-16b-a3b", dict(n_layers=2)),
    ("mamba", "jamba-1.5-large-398b",
     dict(n_layers=2, block_pattern=("m", "a"), moe=None)),
    ("rwkv", "rwkv6-1.6b", dict(n_layers=2)),
    ("sliding_window+moe", "mixtral-8x7b", dict(n_layers=2, window=8)),
)


def chunked_archs_workload(smoke: bool):
    """One admission wave (requests == slots, so chunked and monolithic
    admission pad the batch identically and the only difference measured
    is the chunk-continuation math itself)."""
    slots = 4 if smoke else 8
    new_tokens = 8 if smoke else 12
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(slots):
        plen = int(rng.integers(5, 12))
        prompt = [int(t) for t in rng.integers(1, 200, size=plen)]
        reqs.append(Request(prompt=prompt, max_new_tokens=new_tokens,
                            request_id=i))
    return reqs, {"slots": slots, "new_tokens": new_tokens,
                  "max_len": 64, "chunks": (1, 5)}


def bench_chunked_archs(smoke: bool = False, report=print) -> Dict:
    """Per-architecture chunked-prefill agreement ladder.

    For every architecture feature that makes chunk-continuation prefill
    tolerance-equivalent rather than bit-identical (sliding-window ring
    rotation, MLA latent re-expansion, per-chunk MoE capacity routing,
    mamba/rwkv recurrent-prefix reassociation — see
    ``docs/equivalence.md``), run the chunked continuous engine against
    its own monolithic-prefill oracle and report the worst teacher-forced
    greedy agreement across chunk widths. ``agreement`` (the min) is
    gated in ``scripts/check_bench.py`` against the row's composed
    ``AGREEMENT_BUDGETS`` floor — these rows are the evidence that the
    chunked-prefill arch gates stayed lifted."""
    from repro.serving.equivalence import (agreement_budget,
                                           greedy_token_agreement,
                                           oracle_tokens)
    reqs, wl = chunked_archs_workload(smoke)
    out: Dict = dict(wl, chunks=list(wl["chunks"]), rows={})
    for label, arch, over in CHUNKED_ARCH_ROWS:
        cfg = dataclasses.replace(get_config(arch, reduced=True),
                                  dtype="float32", **over)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        base = ServeConfig(max_batch=wl["slots"], max_len=wl["max_len"],
                           scheduler="continuous")
        oracle_eng = ServeEngine(model, params, base)
        oracle = oracle_tokens(oracle_eng.generate(reqs))
        oracle_eng.close()
        by_chunk = {}
        compared = 0
        for chunk in wl["chunks"]:
            ccfg = dataclasses.replace(base, prefill_chunk=chunk)
            eng = ServeEngine(model, params, ccfg)
            rep = greedy_token_agreement(eng, reqs, oracle)
            eng.close()
            by_chunk[str(chunk)] = rep.rate
            compared = rep.compared
        budget = agreement_budget(
            dataclasses.replace(base, prefill_chunk=wl["chunks"][0]),
            model.cfg)
        row = {"arch": cfg.name,
               "features": list(model.arch_features()),
               "budget": budget,
               "agreement": min(by_chunk.values()),
               "by_chunk": by_chunk,
               "compared": compared}
        out["rows"][label] = row
        report(f"[serving] chunked {label:18s}: agreement "
               f"{row['agreement']:.4f} over {compared} tokens x "
               f"{len(by_chunk)} chunk widths (budget {budget:.3f}, "
               f"{cfg.name})")
    return out


def _spec_model():
    """A deliberately narrow LM for the speculative experiment: decode
    steps must be *dispatch/sync-bound* — the production decode regime
    (per-step latency owned by kernel launch + the per-token host logits
    sync, not FLOPs) that speculation exists to amortize. CPU fake-quant
    gives the low-bit drafter no FLOP discount, so at wider toy widths
    the draft chain's extra FLOPs swamp the step savings and the bench
    would measure the CPU artifact instead of the scheduling win."""
    cfg = get_config("granite-3-8b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32", n_layers=1, d_model=16,
                              n_heads=2, n_kv_heads=1, head_dim=8, d_ff=32,
                              vocab=64)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def speculative_workload():
    """Decode-heavy mixed-length requests on a small slot pool: the shape
    speculation pays for (per-token host syncs and decode dispatches
    dominate; prompts are short so admission is a small fraction of the
    run). Fixed-size at every scale — the acceptance rate and the
    steps-per-token ratio are properties of the draft/verifier pair, not
    of the model width."""
    slots, n = 4, 8
    rng = np.random.default_rng(11)
    reqs = [Request(prompt=[int(t) for t in
                            rng.integers(1, 63, size=8 + (3 * i) % 9)],
                    max_new_tokens=18 + (5 * i) % 10, request_id=i)
            for i in range(n)]
    return reqs, dict(max_len=64, block_size=8, slots=slots, draft_k=6,
                      draft_bits=6)


def bench_speculative(smoke: bool = False, repeats: int = 5,
                      report=print) -> Dict:
    """w8-only verifier decode vs w4-drafts-for-w8 self-speculative decode
    on the paged continuous scheduler (same squant-w8 serving tree; the
    speculative engine adds a squant-w4 drafter of the same checkpoint).

    Greedy acceptance promises output tokens **bit-identical** to
    verifier-only decode — asserted hard here, per request, for every
    draft config measured. Reported are decode throughput for both
    engines, the draft acceptance rate, the p50/p95 of per-slot tokens
    committed per verify cycle (1.0 == verifier-only pace), and the
    engine steps each run took (speculation's win IS steps-per-token:
    every accepted draft saves one full scheduler step — one decode
    dispatch plus one device→host logits sync).

    The headline pair runs at ``draft_bits=6``: acceptance governs
    whether the saved steps outrun the extra draft+verify compute, and
    SQuant at 4 bits on a *random-init* tiny checkpoint is a worst-case
    drafter (near-uniform logits, so low-bit argmax flips constantly —
    real trained checkpoints sit much higher). ``bits_table`` reports
    the full acceptance ladder (w4..w7 drafting for w8) so the tradeoff
    is visible rather than cherry-picked. ``smoke`` is accepted for
    signature parity but changes nothing — see
    :func:`speculative_workload` and :func:`_spec_model`."""
    del smoke
    model, params = _spec_model()
    reqs, wl = speculative_workload()
    new_tokens = sum(r.max_new_tokens for r in reqs)
    out: Dict = dict(wl, useful_tokens=new_tokens)

    def measure(spec: bool, draft_bits: int, reps: int):
        eng = ServeEngine(model, params, ServeConfig(
            max_batch=wl["slots"], max_len=wl["max_len"],
            max_slots=wl["slots"], scheduler="continuous",
            kv_backend="paged", block_size=wl["block_size"],
            quantize_weights="squant", weight_bits=8, speculative=spec,
            draft_bits=draft_bits, draft_k=wl["draft_k"]))
        outs = eng.generate(reqs)                # warm every jit shape
        steps0 = eng.stats()["scheduler"]["steps"]
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            outs = eng.generate(reqs)
            best = min(best, time.perf_counter() - t0)
        st = eng.scheduler.stats()
        m = {"tok_s": new_tokens / best, "wall_ms": best * 1e3,
             "steps_per_run": (st["steps"] - steps0) // reps}
        if spec:
            m.update(acceptance_rate=st["acceptance_rate"],
                     accepted_len=dict(st["accepted_len"]),
                     draft_tokens_proposed=st["draft_tokens_proposed"],
                     draft_tokens_accepted=st["draft_tokens_accepted"])
        eng.close()
        return m, {c.request_id: c.tokens for c in outs}

    w8, ref_tokens = measure(False, wl["draft_bits"], repeats)
    out["w8"] = w8
    report(f"[serving] w8-only    : {w8['tok_s']:7.0f} tok/s "
           f"({w8['steps_per_run']} steps/run)")
    out["bits_table"] = []
    for bits in (4, 5, 6, 7):
        headline = bits == wl["draft_bits"]
        m, toks = measure(True, bits, repeats if headline else 2)
        identical = toks == ref_tokens
        assert identical, \
            f"w{bits}-draft tokens diverged from w8-only decode"
        row = {"draft_bits": bits, "tokens_identical": identical,
               "throughput_ratio": m["tok_s"] / w8["tok_s"], **m}
        out["bits_table"].append(row)
        if headline:
            out["speculative"] = m
        report(f"[serving] w{bits}-draft   : {m['tok_s']:7.0f} tok/s "
               f"({m['steps_per_run']} steps/run, accept "
               f"{m['acceptance_rate']:.2f}, accepted-len p50 "
               f"{m['accepted_len'].get('p50', 0):.1f} p95 "
               f"{m['accepted_len'].get('p95', 0):.1f}, "
               f"{row['throughput_ratio']:.2f}x w8)")
    out["tokens_identical"] = all(r["tokens_identical"]
                                  for r in out["bits_table"])
    out["throughput_ratio"] = out["speculative"]["tok_s"] / w8["tok_s"]
    out["steps_ratio"] = out["speculative"]["steps_per_run"] \
        / max(w8["steps_per_run"], 1)
    report(f"[serving] speculative (w{wl['draft_bits']} drafts) / "
           f"w8-only: throughput {out['throughput_ratio']:.2f}x, steps "
           f"{out['steps_ratio']:.2f}x, tokens identical: "
           f"{out['tokens_identical']}")
    return out


def run(report=print, smoke: bool = False,
        out_path: str = "BENCH_serving.json") -> Dict:
    results = {"smoke": smoke,
               "throughput": bench_throughput(smoke=smoke, report=report),
               "reload": bench_reload_dip(smoke=smoke, report=report),
               "prefill_tail": bench_prefill_tail(smoke=smoke,
                                                  report=report),
               "shared_prefix": bench_shared_prefix(smoke=smoke,
                                                    report=report),
               "paged_chunked": bench_paged_chunked(smoke=smoke,
                                                    report=report),
               "kv_bytes": bench_kv_bytes(smoke=smoke, report=report),
               "chunked_archs": bench_chunked_archs(smoke=smoke,
                                                    report=report),
               "speculative": bench_speculative(smoke=smoke,
                                                report=report)}
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1)
    report(f"[serving] wrote {out_path}")
    return results


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
