"""Serving-scheduler benchmark: round vs continuous batching.

Two experiments on a mixed-length workload (short requests interleaved
with a few long ones — the shape that static rounds serve worst, because
every request in a round waits for the round's longest):

* **throughput** — end-to-end useful tokens/s for the same workload under
  ``scheduler="round"`` vs ``scheduler="continuous"`` (acceptance:
  continuous ≥ 1.2x);
* **reload dip** — a weight version is staged mid-run (a *native* serving
  tree, so staging itself is ~free and the measurement isolates the
  *scheduling* cost of landing a reload, complementing
  ``bench_reload.py``'s staging-contention dip). Per-step useful-token
  rates around the stage→swap window give each engine's decode dip and
  swap lag: the round engine can only swap after its longest in-flight
  request finishes, the continuous engine drains admission and force-swaps
  after ``swap_deadline_ms``.

Writes ``BENCH_serving.json`` (or ``--smoke`` scale for the CI bench
gate, compared against the committed baseline by
``scripts/check_bench.py``).
"""
from __future__ import annotations

import dataclasses
import json
import sys
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.serving.engine import Request, ServeConfig, ServeEngine

DIP_WINDOW = 6          # steps per useful-rate window


def _swap_deadline_ms(smoke: bool) -> float:
    """Continuous force-swap deadline for the reload bench: a handful of
    decode steps at each scale (tiny-model steps are ~4x cheaper)."""
    return 1.5 if smoke else 8.0


def _model(smoke: bool):
    cfg = get_config("granite-3-8b", reduced=True)
    over = dict(dtype="float32")
    if smoke:
        over.update(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                    head_dim=16, d_ff=64, vocab=256)
    cfg = dataclasses.replace(cfg, **over)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def mixed_workload(smoke: bool) -> List[Request]:
    """Mostly-short requests with one long request per round-sized chunk,
    so every static round is dominated by its longest member."""
    n, slots = (10, 4) if smoke else (24, 8)
    long_budget, short_budgets = (24, (3, 4, 6)) if smoke \
        else (64, (6, 8, 10, 12))
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n):
        budget = long_budget if i % slots == 0 \
            else short_budgets[i % len(short_budgets)]
        plen = int(rng.integers(3, 11))
        prompt = [int(t) for t in rng.integers(1, 60, size=plen)]
        reqs.append(Request(prompt=prompt, max_new_tokens=budget,
                            request_id=i))
    return reqs


def _serve_cfg(scheduler: str, smoke: bool, **over) -> ServeConfig:
    slots = 4 if smoke else 8
    return ServeConfig(max_batch=slots, max_len=96 if smoke else 192,
                       scheduler=scheduler, **over)


def bench_throughput(smoke: bool = False, repeats: int = 3,
                     report=print) -> Dict:
    model, params = _model(smoke)
    reqs = mixed_workload(smoke)
    total_tokens = sum(r.max_new_tokens for r in reqs)
    out: Dict = {"requests": len(reqs), "useful_tokens": total_tokens}
    for scheduler in ("round", "continuous"):
        eng = ServeEngine(model, params, _serve_cfg(scheduler, smoke))
        eng.generate(reqs)                       # warm every jit shape
        steps0 = eng.stats()["scheduler"]["steps"]
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            outs = eng.generate(reqs)
            best = min(best, time.perf_counter() - t0)
        assert sum(len(o.tokens) for o in outs) == total_tokens
        steps = eng.stats()["scheduler"]["steps"] - steps0
        eng.close()
        out[scheduler] = {"tok_s": total_tokens / best,
                          "wall_ms": best * 1e3,
                          "steps_per_run": steps // repeats}
        report(f"[serving] {scheduler:10s}: {out[scheduler]['tok_s']:7.0f} "
               f"tok/s ({out[scheduler]['wall_ms']:.0f} ms, "
               f"{out[scheduler]['steps_per_run']} steps)")
    out["ratio"] = out["continuous"]["tok_s"] / out["round"]["tok_s"]
    report(f"[serving] continuous/round throughput ratio: "
           f"{out['ratio']:.2f}x")
    return out


def _dip_metrics(steps: List[dict], stage_idx: int,
                 w: int = DIP_WINDOW) -> Dict:
    """Windowed useful-token rates around the stage→swap interval."""
    rec = [e["recorded"] for e in steps]
    v0 = steps[0]["version"]
    swap_idx = next((i for i, e in enumerate(steps) if e["version"] > v0),
                    None)
    if swap_idx is None:
        raise RuntimeError(
            f"swap never observed in the {len(steps)}-step log (staged at "
            f"step {stage_idx}) — stage earlier or grow the workload")
    steady = sum(rec[max(0, stage_idx - w):stage_idx]) \
        / min(w, max(1, stage_idx))
    hi = min(len(rec) - w, swap_idx + w)
    rates = [sum(rec[i:i + w]) / w
             for i in range(stage_idx, max(stage_idx + 1, hi))]
    min_rate = min(rates)
    return {"steady_rate": steady, "min_rate": min_rate,
            "dip_pct": 100.0 * (1.0 - min_rate / steady),
            "swap_lag_steps": swap_idx - stage_idx}


def bench_reload_dip(smoke: bool = False, report=print) -> Dict:
    model, params = _model(smoke)
    params2 = model.init(jax.random.PRNGKey(1))
    reqs = mixed_workload(smoke)
    stage_step = 5 if smoke else 12
    deadline = _swap_deadline_ms(smoke)
    out: Dict = {"stage_step": stage_step, "swap_deadline_ms": deadline}
    for scheduler in ("round", "continuous"):
        eng = ServeEngine(model, params,
                          _serve_cfg(scheduler, smoke,
                                     swap_deadline_ms=deadline))
        eng.generate(reqs)                       # warm every jit shape
        marks: Dict = {}
        orig_acquire = eng.store.acquire

        def acquire(orig=orig_acquire, marks=marks):
            ver, sms = orig()
            if ver.version >= 2 and "t_swap" not in marks:
                marks["t_swap"] = time.perf_counter()
            return ver, sms

        eng.store.acquire = acquire

        def hook(info, eng=eng, marks=marks):
            if info["step"] == marks["stage_at"] \
                    and "t_stage" not in marks:
                # native serving tree: staging is ~free, isolating the
                # *scheduling* dip from bench_reload's contention dip
                eng.store.stage(serving_params=params2, source="bench",
                                block=True)
                marks["t_stage"] = time.perf_counter()

        eng.on_step = hook
        marks["stage_at"] = eng.scheduler.steps_total + stage_step
        eng.scheduler.step_log = steps = []
        outs = eng.generate(reqs)
        assert sum(len(o.tokens) for o in outs) \
            == sum(r.max_new_tokens for r in reqs)
        m = _dip_metrics(steps, stage_step)
        m["swap_lag_ms"] = (marks["t_swap"] - marks["t_stage"]) * 1e3
        if scheduler == "continuous":
            m["forced_swaps"] = eng.stats()["scheduler"]["forced_swaps"]
        eng.close()
        out[scheduler] = m
        report(f"[serving] reload {scheduler:10s}: steady "
               f"{m['steady_rate']:.1f} tok/step → min {m['min_rate']:.1f} "
               f"(dip {m['dip_pct']:.0f}%), swap lag "
               f"{m['swap_lag_steps']} steps / {m['swap_lag_ms']:.1f} ms")
    out["dip_advantage_pct"] = \
        out["round"]["dip_pct"] - out["continuous"]["dip_pct"]
    report(f"[serving] continuous reload dip is "
           f"{out['dip_advantage_pct']:.0f} pts smaller than round")
    return out


def run(report=print, smoke: bool = False,
        out_path: str = "BENCH_serving.json") -> Dict:
    results = {"smoke": smoke,
               "throughput": bench_throughput(smoke=smoke, report=report),
               "reload": bench_reload_dip(smoke=smoke, report=report)}
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1)
    report(f"[serving] wrote {out_path}")
    return results


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
