"""Table 5 analog: SQuant vs data-free AdaRound (synthetic calibration) and
vs data-driven AdaRound (real calibration — an upper reference the paper's
baselines don't even get), weight-only at 3/4/5 bits on the toy CNN.

Claim under test: SQuant ≥ data-free AdaRound at every width while being
orders of magnitude faster (no data synthesis, no gradients)."""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from _toy import train_cnn_cached
from bench_accuracy import quantize_cnn

METHODS = ("adaround_df", "adaround_real", "squant")
SEEDS = (0, 1)


def run(report=print) -> Dict:
    nets = [train_cnn_cached(seed=s) for s in SEEDS]
    out = {"fp32": float(np.mean([ev(p) for p, _, ev in nets]))}
    report(f"table5,baseline,fp32,acc={out['fp32']:.4f}")
    for bits in (3, 2):
        for method in METHODS:
            accs = []
            t0 = time.perf_counter()
            for params, bn, evaluate in nets:
                q = quantize_cnn(params, bn, method, bits)
                accs.append(evaluate(q))
            ms = (time.perf_counter() - t0) * 1e3 / len(nets)
            acc = float(np.mean(accs))
            out[f"w{bits}_{method}"] = acc
            report(f"table5,{method},w{bits},acc={acc:.4f},"
                   f"std={np.std(accs):.4f},ms={ms:.0f}")
    return out


if __name__ == "__main__":
    run()
