"""Kernel-level microbench: SQuant CASE quality + wall time of the
vectorized implementation vs the sequential pseudocode reference, and
dequant-matmul byte-savings accounting (the serving memory-roofline win).
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.reference import squant_reference
from repro.core.squant import SQuantConfig, squant, squant_codes
from repro.quant.scales import compute_scale


def run(report=print) -> Dict:
    out = {}
    rng = np.random.default_rng(0)
    # CASE quality + speedup vs sequential reference
    w = rng.normal(size=(256, 2048)).astype(np.float32)
    wj = jnp.asarray(w)
    scale = compute_scale(wj, 4, "max")
    codes, delta, _ = squant_codes(wj, scale, bits=4, group_size=128,
                                   enable_k=True, enable_c=True)
    jax.block_until_ready(codes)
    t0 = time.perf_counter()
    for _ in range(5):
        codes, delta, _ = squant_codes(wj, scale, bits=4, group_size=128,
                                       enable_k=True, enable_c=True)
        jax.block_until_ready(codes)
    vec_ms = (time.perf_counter() - t0) / 5 * 1e3
    t0 = time.perf_counter()
    squant_reference(w[:32], np.asarray(scale)[:32], 4, 128)
    seq_ms = (time.perf_counter() - t0) * 1e3 * (256 / 32)
    d = np.asarray(delta)
    out["vec_ms"] = vec_ms
    out["seq_ms_est"] = seq_ms
    report(f"kernels,squant_flip,vec_ms={vec_ms:.2f},"
           f"seq_pseudocode_ms={seq_ms:.0f},"
           f"speedup={seq_ms/max(vec_ms,1e-9):.0f}x,"
           f"row_case_max={np.abs(d.sum(1)).max():.3f}")

    # serving bytes: int4+scales vs bf16
    for bits in (8, 4):
        qt, _ = squant(wj, SQuantConfig(bits=bits, group_size=128))
        dense = w.size * 2  # bf16
        out[f"bytes_w{bits}"] = qt.nbytes()
        report(f"kernels,dequant_matmul,w{bits},bytes={qt.nbytes()},"
               f"vs_bf16={dense},ratio={dense/qt.nbytes():.2f}x")
    return out


if __name__ == "__main__":
    run()
