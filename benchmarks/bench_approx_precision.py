"""Table 6 / Appendix A.3 analog: approximation precision of the data-free
objective.

For every conv layer of the trained toy CNN, run SQuant, then score each
flip against (a) the coefficient-weighted Eq. (6) whose e/k/c come from real
activation second moments (Algorithm 3), and (b) the exact Eq. (4) objective
δ·E[xxᵀ]·δᵀ. Paper reports 93.6% (E&K) / 97.8% (E&K&C) on ResNet18."""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np

from repro.core.hessian import approximation_precision

from _toy import CHANNELS, cnn_forward, texture_batch, train_cnn


def run(report=print) -> Dict:
    params, bn, _ = train_cnn(steps=250)
    rng = np.random.default_rng(3)
    x, _ = texture_batch(rng, 128)
    import jax.numpy as jnp
    _, _, acts = cnn_forward(params, jnp.asarray(x), bn, train=False,
                             capture=True)
    out = {}
    tot_f = tot_c = tot_ex = 0
    for i in range(len(CHANNELS)):
        w = params[f"conv{i}"]["w_conv"]
        kh, kw, ci, co = w.shape
        a = acts[f"conv{i}"]
        patches = jax.lax.conv_general_dilated_patches(
            a, (kh, kw), (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        flat = np.asarray(patches.reshape(-1, ci * kh * kw))
        sel = rng.choice(flat.shape[0], min(4000, flat.shape[0]),
                         replace=False)
        w2d = np.asarray(jnp.transpose(w, (3, 2, 0, 1))
                         .reshape(co, ci * kh * kw))
        rep = approximation_precision(w2d, flat[sel], bits=4,
                                      group_size=kh * kw)
        out[f"conv{i}"] = (rep.flipped, rep.ap, rep.ap_exact)
        tot_f += rep.flipped
        tot_c += rep.correct
        tot_ex += rep.correct_exact
        report(f"table6,conv{i},flipped={rep.flipped},ap={rep.ap:.4f},"
               f"ap_exact={rep.ap_exact:.4f},"
               f"ap_inorder={rep.ap_inorder:.4f}")
    out["total_ap"] = tot_c / max(tot_f, 1)
    out["total_ap_exact"] = tot_ex / max(tot_f, 1)
    report(f"table6,total,flipped={tot_f},ap={out['total_ap']:.4f},"
           f"ap_exact={out['total_ap_exact']:.4f}")
    return out


if __name__ == "__main__":
    run()
