"""Hot-reload benchmark: quantize → stage → swap latency and the
decode-throughput dip a live reload inflicts on serving.

SQuant's pitch is that data-free quantization is cheap enough to run *on*
the serving device between decode rounds. This measures exactly that, via
the versioned ``WeightStore``:

* **staging latency** — wall time for ``stage(fp_params)`` (the batched
  ``quantize_tree`` path) and for a native quantized-checkpoint restore
  (``stage(serving_params)``), on the toy CNN and the reduced LM;
* **swap latency** — the round-boundary ``acquire()`` pointer flip;
* **throughput dip** — decode tokens/s per round on the reduced LM while a
  background reload quantizes + stages concurrently, vs the undisturbed
  baseline.

Writes ``BENCH_reload.json``.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict

import jax
import numpy as np

from repro.configs import get_config
from repro.core.pipeline import quantize_tree
from repro.models.model import build_model
from repro.quant.apply import quantize_params_serving
from repro.serving.engine import Request, ServeConfig, ServeEngine
from repro.serving.weights import WeightStore

from _toy import init_cnn


def _reduced_lm():
    cfg = get_config("granite-3-8b", reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def bench_stage_latency(report=print) -> Dict:
    """quantize→stage→swap wall time per workload and source format."""
    out: Dict = {}
    lm_model, lm_params = _reduced_lm()
    workloads = {
        "toy_cnn": (init_cnn(jax.random.PRNGKey(0)), None),
        "reduced_lm": (lm_params, lm_model),
    }
    for name, (params, _) in workloads.items():
        def quantize_fn(tree):
            return quantize_tree(tree, method="squant", bits=8,
                                 dequantize=True)

        store = WeightStore(quantize_fn, fp_params=params)
        t0 = time.perf_counter()
        store.stage(fp_params=params, source="bench", block=True)
        stage_fp_ms = (time.perf_counter() - t0) * 1e3
        _, swap_ms = store.acquire()

        qtree, meta = quantize_params_serving(params, 8, "squant")
        t0 = time.perf_counter()
        store.stage(serving_params=qtree, source="bench-native", block=True)
        stage_native_ms = (time.perf_counter() - t0) * 1e3
        _, swap2_ms = store.acquire()
        store.close()
        out[name] = {"stage_fp_quantize_ms": stage_fp_ms,
                     "stage_native_quantized_ms": stage_native_ms,
                     "quantize_only_ms": meta["quantize_ms"],
                     "swap_ms": max(swap_ms, swap2_ms)}
        report(f"[reload] {name}: stage(fp→squant w8) {stage_fp_ms:.1f} ms, "
               f"stage(native qdict) {stage_native_ms:.1f} ms, "
               f"swap {max(swap_ms, swap2_ms):.3f} ms")
    return out


def bench_throughput_dip(rounds: int = 10, reload_round: int = 4,
                         max_new: int = 16, report=print) -> Dict:
    """Decode-throughput per round on the reduced LM; a background reload
    (quantize+stage of a fresh fp tree) starts at ``reload_round``."""
    model, params = _reduced_lm()
    _, params2 = _reduced_lm()
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=4, max_len=64,
                                  quantize_weights="squant", weight_bits=8))
    reqs = [Request(prompt=[1, 2, 3, 4], max_new_tokens=max_new,
                    request_id=i) for i in range(4)]
    eng.generate(reqs)                                  # warm the jit cache
    base_version = eng.store.version
    tok_s, swap_ms = [], []
    for r in range(rounds):
        if r == reload_round:
            eng.store.stage(fp_params=params2, source="bench-reload")
        outs = eng.generate(reqs)
        toks = sum(len(o.tokens) for o in outs)
        dec_ms = outs[0].decode_ms
        tok_s.append(toks / (dec_ms / 1e3))
        swap_ms.append(outs[0].swap_ms)
    # normally the reload already swapped in mid-run; if staging outlasted
    # the measured rounds, wait for it and swap so the stats below describe
    # the reloaded version
    assert eng.store.wait_staged(version=base_version, timeout=120), \
        "reload never staged"
    eng.store.acquire()
    eng.close()
    log = eng.stats()["round_log"][1:]                  # skip warmup entry
    baseline = float(np.median(tok_s[:reload_round]))
    during = tok_s[reload_round:]
    dip_pct = 100.0 * (1.0 - min(during) / baseline)
    staged_ms = eng.store.current.staged_ms
    out = {"rounds": rounds, "reload_round": reload_round,
           "decode_tok_s": tok_s,
           "baseline_tok_s": baseline,
           "min_tok_s_during_reload": float(min(during)),
           "dip_pct": dip_pct,
           "staged_ms": staged_ms,
           "swap_ms": swap_ms,
           "versions": [e["version"] for e in log],
           "final_version": eng.store.version}
    report(f"[reload] LM decode: baseline {baseline:.0f} tok/s, during "
           f"reload min {min(during):.0f} tok/s (dip {dip_pct:.1f}%), "
           f"staged in {staged_ms:.0f} ms, final v{eng.store.version}")
    return out


def run(report=print) -> Dict:
    results = {"stage_latency": bench_stage_latency(report=report),
               "throughput_dip": bench_throughput_dip(report=report)}
    with open("BENCH_reload.json", "w") as f:
        json.dump(results, f, indent=1)
    report("[reload] wrote BENCH_reload.json")
    return results


if __name__ == "__main__":
    run()
