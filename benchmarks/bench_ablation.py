"""Table 4 analog: SQuant granularity ablation (E / E&K / E&C / E&K&C) at
3/4-bit weight-only on the toy CNN — the paper's exact ablation, where the
conv 3×3 kernels give SQuant-K its natural granularity.

Claim under test: accuracy(E&K&C) ≥ accuracy(E&K) ≥ accuracy(E) and
accuracy(E&K&C) ≥ accuracy(E&C) (paper Table 4: 2.05 → 40.87 → 52.07 →
60.78 at w3 on ResNet18)."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.pipeline import quantize_tree

from _toy import train_cnn_cached

VARIANTS = ("squant_e", "squant_ek", "squant_ec", "squant")
SEEDS = (0, 1, 2)


def run(report=print) -> Dict:
    nets = [train_cnn_cached(seed=s) for s in SEEDS]
    base = [ev(p) for p, _, ev in nets]
    out = {"fp32": float(np.mean(base))}
    report(f"table4,baseline,fp32,acc={out['fp32']:.4f}")
    for bits in (3, 2):
        for variant in VARIANTS:
            accs = []
            for params, bn, evaluate in nets:
                q, _ = quantize_tree(params, method=variant, bits=bits,
                                     dequantize=True)
                accs.append(evaluate(q))
            out[f"w{bits}_{variant}"] = float(np.mean(accs))
            report(f"table4,{variant},w{bits},acc={np.mean(accs):.4f},"
                   f"std={np.std(accs):.4f},seeds={len(SEEDS)}")
    return out


if __name__ == "__main__":
    run()
