"""Roofline report: reads launch/dryrun.py artifacts and prints the per-cell
three-term roofline table (see EXPERIMENTS.md §Roofline).

Merges each cell's production artifact (memory/compile proof) with its
costing artifact (loop-complete flops + collective bytes). Run
``python -m repro.launch.dryrun --all`` (+ ``--costing``) first.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def load_cells() -> Dict[str, dict]:
    cells: Dict[str, dict] = {}
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        key = f"{d['arch']}__{d['shape']}__{d.get('mesh', '?')}"
        tag = d.get("tag", "")
        slot = tag if tag else "prod"
        cells.setdefault(key, {})[slot] = d
    return cells


def _terms(prod: dict, cost: dict) -> dict:
    """Merged roofline terms: flops/bytes from the costing artifact,
    collectives + memory floor + fit proof from the production artifact."""
    rc = cost.get("roofline", {})
    rp = prod.get("roofline", {})
    comp = rc.get("compute_s", rp.get("compute_s", 0.0))
    mem = rc.get("memory_s", rp.get("memory_s", 0.0))
    floor = rp.get("memory_floor_s", rc.get("memory_floor_s", 0.0))
    coll = rp.get("collective_s", 0.0)
    dom = max((("compute", comp), ("memory", mem), ("collective", coll)),
              key=lambda kv: kv[1])[0]
    # roofline fraction: useful model flops vs the binding resource's time
    bound = max(comp, mem, coll, 1e-30)
    n = cost.get("n_chips", prod.get("n_chips", 256))
    useful_s = cost.get("model_flops", prod.get("model_flops", 0.0)) \
        / n / PEAK_FLOPS
    return {"compute_s": comp, "memory_s": mem, "memory_floor_s": floor,
            "collective_s": coll, "dominant": dom,
            "roofline_frac": useful_s / bound,
            "useful_ratio": rc.get("model_flops_ratio",
                                   rp.get("model_flops_ratio"))}


def row(key: str, cell: dict, slot_prod="prod", slot_cost="cost") -> str:
    prod = cell.get(slot_prod, {})
    cost = cell.get(slot_cost, prod)
    if prod.get("status") == "skip" or cost.get("status") == "skip":
        return f"{key},skip,{prod.get('reason', cost.get('reason', ''))}"
    if prod.get("status") != "ok" and cost.get("status") != "ok":
        return f"{key},error,{str(prod.get('error', '?'))[:120]}"
    t = _terms(prod, cost)
    hbm = prod.get("hbm_per_chip_gb", -1)
    fits = prod.get("fits_16gb")
    ur = t["useful_ratio"]
    return (f"{key},ok,compute_ms={t['compute_s']*1e3:.3f},"
            f"memory_ms={t['memory_s']*1e3:.3f},"
            f"memfloor_ms={t['memory_floor_s']*1e3:.3f},"
            f"collective_ms={t['collective_s']*1e3:.3f},"
            f"dominant={t['dominant']},"
            f"roofline_frac={t['roofline_frac']:.3f},"
            f"useful_flops_ratio={ur if ur is None else round(ur, 3)},"
            f"hbm_gb={hbm:.2f},fits={fits}")


def run(report=print) -> Dict:
    cells = load_cells()
    out = {}
    if not cells:
        report("roofline,no-artifacts,run launch/dryrun.py first")
        return out
    for key in sorted(cells):
        line = row(key, cells[key])
        out[key] = line
        report("roofline," + line)
        # hillclimb variants: pair <tag> with cost-<tag> when present
        extra = [s for s in cells[key]
                 if s not in ("prod", "cost") and not s.startswith("cost")]
        for s in sorted(extra):
            line = row(f"{key}[{s}]", cells[key], slot_prod=s,
                       slot_cost=f"cost-{s}"
                       if f"cost-{s}" in cells[key] else s)
            out[f"{key}[{s}]"] = line
            report("roofline," + line)
    return out


if __name__ == "__main__":
    run()
