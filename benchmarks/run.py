"""Benchmark runner — one module per paper table. Prints CSV lines
``name,...metrics`` and a summary. Usage: python -m benchmarks.run [tables]
"""
from __future__ import annotations

import sys
import time


TABLES = ("accuracy", "ablation", "adaround", "time", "approx_precision",
          "kernels", "roofline", "reload", "serving")


def main() -> None:
    sys.path.insert(0, __file__.rsplit("/", 1)[0])
    names = sys.argv[1:] or list(TABLES)
    t00 = time.time()
    for name in names:
        mod = __import__(f"bench_{name}")
        print(f"### bench_{name} " + "#" * 40, flush=True)
        t0 = time.time()
        mod.run(report=lambda s: print(s, flush=True))
        print(f"### bench_{name} done in {time.time()-t0:.1f}s", flush=True)
    print(f"### all benches done in {time.time()-t00:.1f}s")


if __name__ == '__main__':
    main()
