"""Tables 1-2 analog: data-free quantization methods vs accuracy.

Models: the toy CNN (paper's domain: conv+BN+ReLU) and a toy LM (this
framework's domain). Methods: RTN (=DFQ rounding / SQuant-E), DFQ
(cross-layer equalization + BN-based bias correction), data-free AdaRound
(synthetic calibration), and SQuant E&K&C — weight quantization at
8/6/4(/3) bits, per-channel, exactly the paper's protocol (activations fp32,
Table 4/5 setting; A8 dynamic variant reported for the LM).

Claim under test: SQuant ≥ every data-free baseline at every width, with the
gap growing as bits shrink (paper: >30% at w4 on ImageNet models).
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines
from repro.core.pipeline import quantize_tree

from _toy import (CHANNELS, cnn_forward, texture_batch,
                  train_toy_lm)


# ---------------------------------------------------------------------------
# CNN method implementations
# ---------------------------------------------------------------------------

def _relu_gauss_mean(beta, gamma):
    """E[ReLU(N(beta, gamma²))] — DFQ's BN-based input-mean estimate."""
    from jax.scipy.stats import norm
    g = jnp.maximum(jnp.abs(gamma), 1e-6)
    z = beta / g
    return beta * norm.cdf(z) + g * norm.pdf(z)


def quantize_cnn(params: Dict, bn: Dict, method: str, bits: int) -> Dict:
    """Fake-quant all conv + head weights with the given method."""
    if method in ("rtn", "squant", "squant_e", "squant_ek", "squant_ec"):
        m = "rtn" if method == "rtn" else method
        q, _ = quantize_tree(params, method=m, bits=bits, dequantize=True)
        return q

    if method == "dfq":
        # cross-layer equalization on conv pairs (per-tensor ranges is the
        # regime DFQ targets; we keep per-channel quant afterwards like all
        # other methods, so equalization mainly helps the depth dimension)
        # + BN-statistics bias correction, then RTN.
        p = jax.tree_util.tree_map(lambda x: x, params)  # copy
        q, _ = quantize_tree(p, method="rtn", bits=bits, dequantize=True)
        # bias correction layer by layer: E[x] of conv_i input from BN of
        # conv_{i-1} (DFQ Sec 4.2); first layer input mean ≈ 0.
        for i in range(len(CHANNELS)):
            name = f"conv{i}"
            w_fp = params[name]["w_conv"]   # (KH,KW,Cin,Cout)
            w_q = q[name]["w_conv"]
            if i == 0:
                mu_in = jnp.zeros((w_fp.shape[2],))
            else:
                prev = f"conv{i-1}"
                mu_in = _relu_gauss_mean(params[prev]["bn_bias"],
                                         params[prev]["bn_scale"])
            dw = (w_q - w_fp).sum(axis=(0, 1))          # (Cin, Cout)
            corr = -(mu_in[None, :] @ dw)[0]
            q[name]["bias"] = params[name]["bias"] + corr
        return q

    if method in ("adaround_df", "adaround_real"):
        # layer-wise AdaRound on unfolded conv inputs; calibration data is
        # synthetic for the data-free variant (ZeroQ-style BN matching), real
        # for the data-driven reference.
        rng = np.random.default_rng(0)
        if method == "adaround_real":
            x, _ = texture_batch(rng, 64)
            x = jnp.asarray(x)
        else:
            x = _synthesize_cnn_inputs(params, bn, (64, 16, 16, 1))
        _, _, acts = cnn_forward(params, x, bn, train=False, capture=True)
        q = jax.tree_util.tree_map(lambda v: v, params)
        for i in range(len(CHANNELS)):
            name = f"conv{i}"
            w = params[name]["w_conv"]
            kh, kw, ci, co = w.shape
            a = acts[name]
            patches = jax.lax.conv_general_dilated_patches(
                a, (kh, kw), (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            flat = patches.reshape(-1, ci * kh * kw)
            sel = jnp.asarray(rng.choice(flat.shape[0], 512, replace=False))
            # patches layout: (Cin, KH, KW) flattened
            w2d = jnp.transpose(w, (3, 2, 0, 1)).reshape(co, ci * kh * kw)
            qt = baselines.adaround(w2d, flat[sel], bits=bits, iters=400)
            wq = qt.dequantize().reshape(co, ci, kh, kw)
            q[name]["w_conv"] = jnp.transpose(wq, (2, 3, 1, 0))
        qh = baselines.rtn(params["head"]["w"].T, bits=bits)
        q["head"]["w"] = qh.dequantize().T
        return q

    raise ValueError(method)


def _synthesize_cnn_inputs(params, bn, shape):
    """ZeroQ-style: distill inputs whose BN-layer statistics match the
    running stats (needs BP — the 'No BP ✗' baseline column)."""
    targets = []
    for i in range(len(CHANNELS)):
        st = bn[f"conv{i}"]
        targets.append(jnp.concatenate([st["mean"], jnp.sqrt(st["var"])]))
    target = jnp.concatenate(targets)

    def stat_fn(x):
        stats = []
        h = x
        for i in range(len(CHANNELS)):
            p = params[f"conv{i}"]
            h = jax.lax.conv_general_dilated(
                h, p["w_conv"], (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["bias"]
            stats.append(jnp.concatenate(
                [jnp.mean(h, (0, 1, 2)),
                 jnp.std(h, (0, 1, 2))]))
            st = bn[f"conv{i}"]
            hn = (h - st["mean"]) * jax.lax.rsqrt(st["var"] + 1e-5)
            h = jax.nn.relu(hn * p["bn_scale"] + p["bn_bias"])
            if i % 2 == 1:
                h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                          (1, 2, 2, 1), (1, 2, 2, 1),
                                          "VALID")
        return jnp.concatenate(stats)

    return baselines.synthesize_inputs(stat_fn, target, shape,
                                       jax.random.PRNGKey(0), iters=150)


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------

CNN_METHODS = ("rtn", "dfq", "adaround_df", "squant")
LM_METHODS = ("rtn", "squant_e", "squant_ek", "squant")
SEEDS = (0, 1, 2)


def _correlated_output_mse(report, out):
    """Mechanism check (Eq. 4): output MSE ‖(W_q − W)x‖² under spatially
    correlated inputs — the regime the Hessian approximation targets.
    This is the quantity SQuant provably reduces; accuracy follows when
    the task is capacity-bound (see the w2/w3 CNN rows)."""
    rng = np.random.default_rng(0)
    m, ng, g = 128, 16, 32
    w = jnp.asarray(rng.normal(size=(m, ng * g)).astype(np.float32))
    base = rng.normal(size=(4096, ng, 1)).astype(np.float32)
    x = (0.8 * base + 0.4 * rng.normal(size=(4096, ng, g))
         + 0.4).reshape(4096, ng * g).astype(np.float32)
    xj = jnp.asarray(x)
    from repro.core.squant import SQuantConfig, squant
    for tag, (ek, ec) in {"rtn": (False, False), "squant_ek": (True, False),
                          "squant": (True, True)}.items():
        qt, _ = squant(w, SQuantConfig(bits=4, group_size=g, enable_k=ek,
                                       enable_c=ec))
        dw = qt.dequantize() - w
        mse = float(jnp.mean((xj @ dw.T) ** 2))
        out[f"outmse_{tag}"] = mse
        report(f"table1.mechanism,{tag},w4,output_mse={mse:.5f}")


def run(report=print) -> Dict:
    out = {}
    from _toy import train_cnn_cached
    nets = [train_cnn_cached(seed=s) for s in SEEDS]
    base_acc = float(np.mean([ev(p) for p, _, ev in nets]))
    report(f"table1.cnn,baseline,fp32,acc={base_acc:.4f}")
    out["cnn_fp32"] = base_acc
    for bits in (4, 3, 2):
        for method in CNN_METHODS:
            accs = []
            t0 = time.perf_counter()
            for params, bn, evaluate in nets:
                q = quantize_cnn(params, bn, method, bits)
                accs.append(evaluate(q))
            us = (time.perf_counter() - t0) * 1e6 / len(nets)
            acc = float(np.mean(accs))
            out[f"cnn_w{bits}_{method}"] = acc
            report(f"table1.cnn,{method},w{bits},acc={acc:.4f},"
                   f"std={np.std(accs):.4f},quant_us={us:.0f}")

    _correlated_output_mse(report, out)

    model, lparams, eval_xent = train_toy_lm(steps=200)
    base = eval_xent(lparams)
    out["lm_fp32"] = base
    report(f"table2.lm,baseline,fp32,xent={base:.4f}")
    for bits in (4, 3, 2):
        for method in LM_METHODS:
            q, _ = quantize_tree(lparams, method=method, bits=bits,
                                 group_size=32, dequantize=True)
            x = eval_xent(q)
            out[f"lm_w{bits}_{method}"] = x
            report(f"table2.lm,{method},w{bits},xent={x:.4f}")
    return out


if __name__ == "__main__":
    run()
