"""Table 3 analog: quantization wall-time.

The paper's headline systems claim: SQuant quantizes whole networks in
milliseconds (no data, no BP) while generative DFQ takes minutes-hours.
Here: SQuant vs data-free AdaRound (ZeroQ-style synthesis + gradient
rounding) on the toy CNN, plus per-layer SQuant timing on mid-size LM
weight matrices (up to granite-3-8b-sized layers), plus the serial
(per-layer sync) vs batched (bucketed, one sync) pipeline comparison —
run as a script it writes the batched-pipeline numbers to
``BENCH_pipeline.json``.
"""
from __future__ import annotations

import json
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import quantize_tree
from repro.core.squant import SQuantConfig, squant
from repro.quant.qtypes import QuantizedTensor

from _toy import train_cnn
from bench_accuracy import quantize_cnn


def _tree_codes(tree):
    leaves = jax.tree_util.tree_leaves(
        tree, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    return [np.asarray(l.codes()) for l in leaves
            if isinstance(l, QuantizedTensor)]


def bench_pipeline(report=print) -> Dict:
    """Serial per-layer loop vs batched bucketed vs sharded pipeline.

    Toy CNN + one reduced LM; asserts all paths emit identical int8 codes.
    The sharded column row-partitions each bucket over a 1-axis 'data' mesh
    spanning every host device (1 on the plain CPU container; run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a real
    multi-device measurement). Returns a ``BENCH_pipeline.json``-compatible
    dict.
    """
    from repro.configs import get_config
    from repro.launch.mesh import make_quantize_mesh
    from repro.models.model import build_model

    out: Dict = {}
    mesh = make_quantize_mesh()
    out["pipeline_mesh_devices"] = int(mesh.size)
    modes = {"serial": {"batched": False},
             "batched": {},
             "sharded": {"mesh": mesh}}
    cnn_params, _, _ = train_cnn(steps=10)
    lm_cfg = get_config("granite-3-8b", reduced=True)
    lm_params = build_model(lm_cfg).init(jax.random.PRNGKey(0))

    reps = 7
    for name, params in (("cnn", cnn_params), ("lm", lm_params)):
        times = {mode: float("inf") for mode in modes}
        trees = {}
        for mode, kw in modes.items():                    # warm the jit cache
            quantize_tree(params, method="squant", bits=4, **kw)
        for _ in range(reps):       # interleave modes so machine drift cancels
            for mode, kw in modes.items():
                t0 = time.perf_counter()
                trees[mode], rep = quantize_tree(params, method="squant",
                                                 bits=4, **kw)
                ms = (time.perf_counter() - t0) * 1e3
                if ms < times[mode]:
                    times[mode] = ms
                    if mode == "batched":   # breakdown from the min rep, so
                        # dispatch+sync stay consistent with the reported total
                        out[f"pipeline_{name}_dispatch_ms"] = rep.dispatch_millis
                        out[f"pipeline_{name}_sync_ms"] = rep.sync_millis
                        out[f"pipeline_{name}_buckets"] = len(rep.buckets)
                        out[f"pipeline_{name}_layers"] = len(rep.layers)
        for mode in modes:
            out[f"pipeline_{name}_{mode}_ms"] = times[mode]
        base = _tree_codes(trees["serial"])
        identical = all(
            np.array_equal(a, b)
            for mode in ("batched", "sharded")
            for a, b in zip(base, _tree_codes(trees[mode])))
        out[f"pipeline_{name}_codes_identical"] = bool(identical)
        out[f"pipeline_{name}_speedup"] = times["serial"] / max(
            times["batched"], 1e-9)
        report(f"pipeline,{name},serial_ms={times['serial']:.1f},"
               f"batched_ms={times['batched']:.1f},"
               f"sharded_ms={times['sharded']:.1f},"
               f"speedup={out[f'pipeline_{name}_speedup']:.2f}x,"
               f"identical={identical}")
    return out


def run(report=print) -> Dict:
    out = {}
    params, bn, _ = train_cnn(steps=60)   # quality irrelevant here

    # whole-network quantization time (second call = steady-state, jitted)
    for method in ("rtn", "squant"):
        quantize_tree(params, method=method, bits=4, dequantize=True)
        t0 = time.perf_counter()
        _, rep = quantize_tree(params, method=method, bits=4,
                               dequantize=True)
        ms = (time.perf_counter() - t0) * 1e3
        out[f"cnn_{method}_ms"] = ms
        report(f"table3,cnn,{method},total_ms={ms:.1f},"
               f"layers={len(rep.layers)}")

    t0 = time.perf_counter()
    quantize_cnn(params, bn, "adaround_df", 4)
    ms = (time.perf_counter() - t0) * 1e3
    out["cnn_adaround_df_ms"] = ms
    report(f"table3,cnn,adaround_df,total_ms={ms:.1f},layers=5")

    # per-layer SQuant timing at LM-layer scale (steady-state, jitted)
    rng = np.random.default_rng(0)
    for (m, n) in ((1024, 1024), (4096, 4096), (4096, 12800)):
        w = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
        cfg = SQuantConfig(bits=4, group_size=128)
        qt, _ = squant(w, cfg)                      # compile
        jax.block_until_ready(qt.data)
        t0 = time.perf_counter()
        for _ in range(3):
            qt, _ = squant(w, cfg)
            jax.block_until_ready(qt.data)
        ms = (time.perf_counter() - t0) / 3 * 1e3
        out[f"layer_{m}x{n}_ms"] = ms
        report(f"table3,layer,{m}x{n},squant_ms={ms:.2f}")

    out.update(bench_pipeline(report))
    return out


if __name__ == "__main__":
    res = run()
    pipe = {k: v for k, v in res.items() if k.startswith("pipeline_")}
    with open("BENCH_pipeline.json", "w") as f:
        json.dump(pipe, f, indent=1)
    print(f"wrote BENCH_pipeline.json ({len(pipe)} metrics)")
