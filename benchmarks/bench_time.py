"""Table 3 analog: quantization wall-time.

The paper's headline systems claim: SQuant quantizes whole networks in
milliseconds (no data, no BP) while generative DFQ takes minutes-hours.
Here: SQuant vs data-free AdaRound (ZeroQ-style synthesis + gradient
rounding) on the toy CNN, plus per-layer SQuant timing on mid-size LM
weight matrices (up to granite-3-8b-sized layers).
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import quantize_tree
from repro.core.squant import SQuantConfig, squant

from _toy import train_cnn
from bench_accuracy import quantize_cnn


def run(report=print) -> Dict:
    out = {}
    params, bn, _ = train_cnn(steps=60)   # quality irrelevant here

    # whole-network quantization time (second call = steady-state, jitted)
    for method in ("rtn", "squant"):
        quantize_tree(params, method=method, bits=4, dequantize=True)
        t0 = time.perf_counter()
        _, rep = quantize_tree(params, method=method, bits=4,
                               dequantize=True)
        ms = (time.perf_counter() - t0) * 1e3
        out[f"cnn_{method}_ms"] = ms
        report(f"table3,cnn,{method},total_ms={ms:.1f},"
               f"layers={len(rep.layers)}")

    t0 = time.perf_counter()
    quantize_cnn(params, bn, "adaround_df", 4)
    ms = (time.perf_counter() - t0) * 1e3
    out["cnn_adaround_df_ms"] = ms
    report(f"table3,cnn,adaround_df,total_ms={ms:.1f},layers=5")

    # per-layer SQuant timing at LM-layer scale (steady-state, jitted)
    rng = np.random.default_rng(0)
    for (m, n) in ((1024, 1024), (4096, 4096), (4096, 12800)):
        w = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
        cfg = SQuantConfig(bits=4, group_size=128)
        qt, _ = squant(w, cfg)                      # compile
        jax.block_until_ready(qt.data)
        t0 = time.perf_counter()
        for _ in range(3):
            qt, _ = squant(w, cfg)
            jax.block_until_ready(qt.data)
        ms = (time.perf_counter() - t0) / 3 * 1e3
        out[f"layer_{m}x{n}_ms"] = ms
        report(f"table3,layer,{m}x{n},squant_ms={ms:.2f}")
    return out


if __name__ == "__main__":
    run()
