"""Container-scale stand-ins for the paper's evaluation models.

* ``ToyCNN`` — conv(3×3)+BN+ReLU stack + dense head: the architecture family
  the paper evaluates (conv kernels give SQuant-K its natural granularity,
  BN gives DFQ/ZeroQ their statistics). Trained on a deterministic synthetic
  5-class texture task to >90% accuracy in seconds on CPU.
* ``train_toy_lm`` — a reduced transformer LM on the Markov stream (the
  framework's serving domain), for perplexity-based comparisons.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# synthetic texture classification
# ---------------------------------------------------------------------------

N_CLASSES = 5


def texture_batch(rng: np.random.Generator, n: int, size: int = 16
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic 5-class texture images (stripes/checks/blobs)."""
    xs = np.zeros((n, size, size, 1), np.float32)
    ys = rng.integers(0, N_CLASSES, size=n)
    xx, yy = np.meshgrid(np.arange(size), np.arange(size))
    for i in range(n):
        f = rng.uniform(0.5, 1.5)
        ph = rng.uniform(0, 2 * np.pi)
        c = ys[i]
        if c == 0:
            img = np.sin(f * xx + ph)
        elif c == 1:
            img = np.sin(f * yy + ph)
        elif c == 2:
            img = np.sin(f * (xx + yy) / 1.4 + ph)
        elif c == 3:
            img = np.sign(np.sin(f * xx + ph) * np.sin(f * yy + ph))
        else:
            img = np.cos(f * np.hypot(xx - size / 2, yy - size / 2) / 2 + ph)
        xs[i, :, :, 0] = img + rng.normal(0, 0.15, size=(size, size))
    return xs, ys.astype(np.int32)


# ---------------------------------------------------------------------------
# ToyCNN: conv + BN + ReLU ×4 → GAP → dense
# ---------------------------------------------------------------------------

CHANNELS = (16, 24, 32, 32)


def init_cnn(key) -> Dict:
    params: Dict = {}
    cin = 1
    ks = jax.random.split(key, len(CHANNELS) + 1)
    for i, cout in enumerate(CHANNELS):
        params[f"conv{i}"] = {
            "w_conv": jax.random.normal(ks[i], (3, 3, cin, cout),
                                        jnp.float32)
            * np.sqrt(2.0 / (9 * cin)),
            "bias": jnp.zeros((cout,), jnp.float32),
            "bn_scale": jnp.ones((cout,), jnp.float32),
            "bn_bias": jnp.zeros((cout,), jnp.float32),
        }
        cin = cout
    params["head"] = {"w": jax.random.normal(
        ks[-1], (cin, N_CLASSES), jnp.float32) * 0.05}
    return params


def init_bn_state() -> Dict:
    return {f"conv{i}": {"mean": jnp.zeros((c,), jnp.float32),
                         "var": jnp.ones((c,), jnp.float32)}
            for i, c in enumerate(CHANNELS)}


def cnn_forward(params: Dict, x: jnp.ndarray, bn_state: Dict,
                train: bool = False, capture: bool = False):
    """Returns (logits, new_bn_state, activations?)."""
    new_state = {}
    acts = {}
    h = x
    for i in range(len(CHANNELS)):
        p = params[f"conv{i}"]
        if capture:
            acts[f"conv{i}"] = h
        h = jax.lax.conv_general_dilated(
            h, p["w_conv"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = h + p["bias"]
        if train:
            mu = jnp.mean(h, axis=(0, 1, 2))
            var = jnp.var(h, axis=(0, 1, 2))
            st = bn_state[f"conv{i}"]
            new_state[f"conv{i}"] = {
                "mean": 0.9 * st["mean"] + 0.1 * mu,
                "var": 0.9 * st["var"] + 0.1 * var}
        else:
            st = bn_state[f"conv{i}"]
            mu, var = st["mean"], st["var"]
            new_state[f"conv{i}"] = st
        h = (h - mu) * jax.lax.rsqrt(var + 1e-5)
        h = h * p["bn_scale"] + p["bn_bias"]
        h = jax.nn.relu(h)
        if i % 2 == 1:
            h = jax.lax.reduce_window(
                h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                "VALID")
    feat = jnp.mean(h, axis=(1, 2))
    logits = feat @ params["head"]["w"]
    if capture:
        return logits, new_state, acts
    return logits, new_state


_CNN_CACHE = {}


def train_cnn_cached(steps: int = 250, seed: int = 0):
    key = (steps, seed)
    if key not in _CNN_CACHE:
        _CNN_CACHE[key] = train_cnn(steps=steps, seed=seed)
    return _CNN_CACHE[key]


def train_cnn(steps: int = 300, batch: int = 64, lr: float = 2e-3,
              seed: int = 0):
    """Returns (params, bn_state, eval_fn, accuracy)."""
    rng = np.random.default_rng(seed)
    params = init_cnn(jax.random.PRNGKey(seed))
    bn = init_bn_state()
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)

    @jax.jit
    def step(params, bn, m, v, x, y, t):
        def loss_fn(p):
            logits, new_bn = cnn_forward(p, x, bn, train=True)
            oh = jax.nn.one_hot(y, N_CLASSES)
            l = -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * oh, -1))
            return l, new_bn
        (l, new_bn), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        m = jax.tree_util.tree_map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree_util.tree_map(lambda a, b: 0.999 * a + 0.001 * b * b,
                                   v, g)
        mh = jax.tree_util.tree_map(lambda a: a / (1 - 0.9 ** t), m)
        vh = jax.tree_util.tree_map(lambda a: a / (1 - 0.999 ** t), v)
        params = jax.tree_util.tree_map(
            lambda p, a, b: p - lr * a / (jnp.sqrt(b) + 1e-8),
            params, mh, vh)
        return params, new_bn, m, v, l

    for t in range(1, steps + 1):
        x, y = texture_batch(rng, batch)
        params, bn, m, v, l = step(params, bn, m, v, jnp.asarray(x),
                                   jnp.asarray(y), t)

    def evaluate(p, n: int = 1000, seed: int = 999) -> float:
        erng = np.random.default_rng(seed)
        x, y = texture_batch(erng, n)
        logits, _ = jax.jit(
            lambda pp, xx: cnn_forward(pp, xx, bn, train=False))(
                p, jnp.asarray(x))
        return float(np.mean(np.argmax(np.asarray(logits), -1) == y))

    return params, bn, evaluate


# ---------------------------------------------------------------------------
# toy LM
# ---------------------------------------------------------------------------

def train_toy_lm(steps: int = 120, seed: int = 0):
    """Reduced granite on the Markov stream; returns (model, params,
    eval_xent_fn)."""
    import dataclasses as dc
    from repro.configs import get_config
    from repro.data.synthetic import markov_batches
    from repro.models.model import build_model
    from repro.training.optimizer import AdamWConfig, adamw_init
    from repro.training.train_loop import make_train_step

    cfg = get_config("granite-3-8b", reduced=True)
    cfg = dc.replace(cfg, dtype="float32", n_layers=4, d_model=64,
                     n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                     vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=10, decay_steps=steps)
    stepf = jax.jit(make_train_step(model, ocfg))
    it = (jax.tree_util.tree_map(jnp.asarray, b)
          for b in markov_batches(16, 64, cfg.vocab, seed=7))
    for _ in range(steps):
        params, opt, metrics = stepf(params, opt, next(it))

    eval_batches = [jax.tree_util.tree_map(jnp.asarray, b) for b, _ in
                    zip(markov_batches(16, 64, cfg.vocab, seed=7,
                                       start=100_000), range(4))]

    @jax.jit
    def _xent(p, b):
        return model.train_loss(p, b)[1]["xent"]

    def eval_xent(p) -> float:
        return float(np.mean([float(_xent(p, b)) for b in eval_batches]))

    return model, params, eval_xent
