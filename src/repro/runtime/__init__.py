"""Runtime services: straggler monitoring, elastic re-meshing."""
from repro.runtime.monitor import StepMonitor  # noqa: F401
from repro.runtime.elastic import choose_mesh_shape  # noqa: F401
