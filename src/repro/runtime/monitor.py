"""Straggler detection: per-step wall-time EWMA with a flag threshold.

At fleet scale the same monitor runs per host; persistent stragglers are
reported to the coordinator which can evict the host (checkpoint/restart
handles the membership change — see runtime/elastic.py). In this container
the monitor is exercised by tests with synthetic timings.
"""
from __future__ import annotations

from typing import List


class StepMonitor:
    def __init__(self, factor: float = 3.0, alpha: float = 0.1,
                 warmup: int = 3):
        self.factor = factor
        self.alpha = alpha
        self.warmup = warmup
        self.ewma = 0.0
        self.count = 0
        self.flagged: List[int] = []

    def record(self, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self.count += 1
        if self.count <= self.warmup:
            # prime the EWMA; never flag during warmup (compile steps)
            self.ewma = dt if self.ewma == 0.0 else \
                (1 - self.alpha) * self.ewma + self.alpha * dt
            return False
        is_slow = dt > self.factor * self.ewma
        if is_slow:
            self.flagged.append(self.count)
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_slow
