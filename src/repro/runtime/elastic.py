"""Elastic scaling: re-factorize the mesh for a changed chip count and
reshard the latest checkpoint onto it.

Policy: keep the model axis as close to the preferred TP degree as the
device count allows (TP must divide the head/ffn dims), put the rest in
data (FSDP/DP), and add the pod axis only for multi-pod counts. Checkpoints
are shard-agnostic (see checkpoint/checkpointer.py), so a restore onto the
new mesh is just ``restore(..., shardings=make_param_shardings(new_mesh))``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.distributed import compat


def choose_mesh_shape(n_devices: int, prefer_model: int = 16,
                      pod_size: Optional[int] = None
                      ) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """(shape, axis_names) for an arbitrary device count."""
    if pod_size and n_devices > pod_size and n_devices % pod_size == 0:
        pods = n_devices // pod_size
        inner, names = choose_mesh_shape(pod_size, prefer_model)
        return (pods,) + inner, ("pod",) + names
    model = 1
    for cand in range(min(prefer_model, n_devices), 0, -1):
        if n_devices % cand == 0:
            model = cand
            break
    return (n_devices // model, model), ("data", "model")


def make_elastic_mesh(n_devices: Optional[int] = None,
                      prefer_model: int = 16, pod_size: Optional[int] = None):
    n = n_devices or len(jax.devices())
    shape, names = choose_mesh_shape(n, prefer_model, pod_size)
    return compat.make_mesh(shape, names)
