import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). 512 host devices emulate the 2-pod production mesh.
# CI override (still before any jax import): debug meshes for subprocess
# tests use 8 devices.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import math          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import warnings      # noqa: E402

warnings.filterwarnings("ignore")

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402

from repro.configs import SHAPES, get_config, list_archs      # noqa: E402
from repro.distributed import compat                          # noqa: E402
from repro.distributed.sharding import (logical_to_mesh,      # noqa: E402
                                        make_cache_shardings,
                                        make_param_shardings)
from repro.launch.mesh import make_production_mesh            # noqa: E402
from repro.models.model import build_model                    # noqa: E402
from repro.training.optimizer import AdamWConfig, adamw_init  # noqa: E402
from repro.training.train_loop import make_train_step         # noqa: E402

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link (conservative single-link figure)

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8,
                "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "f8e4m3fn": 1, "f8e5m2": 1,
                "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_TYPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9]+m[0-9]+(?:fn)?)?)\[([0-9,]*)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([0-9]+),([0-9]+)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(ls: str) -> int:
    m = _GROUPS_EXPLICIT_RE.search(ls)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(ls)
    if m:
        return int(m.group(2))      # [groups, group_size]<=[N]
    return 1


_COMP_DEF_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str):
    """name → list of instruction lines, plus the entry computation name."""
    comps = {}
    entry = None
    cur = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            m = _COMP_DEF_RE.match(line)
            if m and stripped.endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
        else:
            if stripped == "}":
                cur = None
            else:
                comps[cur].append(stripped)
    return comps, entry


def _loop_multipliers(comps, entry):
    """Execution-count multiplier per computation: while bodies run
    trip-count times (trip read from the largest constant in the loop's
    condition computation — scans compare the induction var against it)."""
    mult = {name: 0.0 for name in comps}
    if entry is None:
        return {name: 1.0 for name in comps}
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    while order:
        name = order.pop()
        m = mult[name]
        for ls in comps.get(name, ()):
            wm = _WHILE_RE.search(ls)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                consts = [int(c) for c in _CONST_RE.findall(
                    "\n".join(comps.get(cond, ())))]
                trip = max(consts) if consts else 1
                mult[body] = mult.get(body, 0.0) + m * trip
                if body not in seen:
                    seen.add(body)
                    order.append(body)
                continue
            for callee in _CALLS_RE.findall(ls):
                if callee in comps and callee not in seen:
                    mult[callee] = mult.get(callee, 0.0) + m
                    seen.add(callee)
                    order.append(callee)
    for name in comps:
        mult.setdefault(name, 1.0)
        if mult[name] == 0.0:
            mult[name] = 1.0   # unreached (e.g. dead fusions): count once
    return mult


def collective_bytes_from_hlo(hlo_text: str, loop_aware: bool = True) -> dict:
    """Per-chip *wire* bytes of every collective in the (post-SPMD,
    per-device) HLO module — operand-size convention: all-reduce≈result,
    all-gather≈result/k, reduce-scatter≈result·k, a2a/cp≈result.
    loop_aware=True multiplies collectives inside while bodies (scans) by
    their trip counts, recovering totals XLA's flat text hides."""
    comps, entry = _split_computations(hlo_text)
    mult = _loop_multipliers(comps, entry) if loop_aware else \
        {n: 1.0 for n in comps}
    out = {k: 0.0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for cname, lines in comps.items():
        w = mult.get(cname, 1.0)
        for ls in lines:
            if "-done(" in ls:
                continue
            for kind in _COLLECTIVES:
                if f" {kind}(" not in ls and f" {kind}-start(" not in ls:
                    continue
                lhs = ls.split(f" {kind}", 1)[0]
                sizes = [_shape_bytes(d, s)
                         for d, s in _TYPE_RE.findall(lhs)]
                res = max(sizes) if sizes else 0.0
                k = _group_size(ls)
                if kind == "all-gather":
                    b = res / max(k, 1)
                elif kind == "reduce-scatter":
                    b = res * k
                else:
                    b = res
                out[kind] += b * w
                count[kind] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = count
    return out


# ---------------------------------------------------------------------------
# analytic model FLOPs
# ---------------------------------------------------------------------------

def count_params(model) -> dict:
    shapes = model.param_shapes()
    total = 0
    expert = 0
    embed = 0
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for kp, leaf in flat:
        path = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in kp)
        n = int(np.prod(leaf.shape))
        total += n
        if "moe" in path and path[-1] == "w":
            expert += n
        if path[-1] == "embedding" or "lm_head" in path:
            embed += n
    cfg = model.cfg
    active = total - expert
    if cfg.moe is not None and expert:
        active += expert * cfg.moe.top_k / cfg.moe.n_experts
    return {"total": total, "expert": expert, "embed": embed,
            "active": int(active),
            "active_nonembed": int(active - embed)}


def model_flops(model, shape) -> float:
    """MODEL_FLOPS: 6·N_active·tokens for training, 2·N_active·tokens for
    forward-only (prefill/decode); N excludes embedding tables (lookup) but
    includes the LM head matmul."""
    p = count_params(model)
    cfg = model.cfg
    n = p["active_nonembed"] + cfg.d_model * cfg.vocab  # head matmul
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: one token/seq


# ---------------------------------------------------------------------------
# per-cell dry-run
# ---------------------------------------------------------------------------

def auto_microbatches(cfg, shape, dp_total: int, budget_gb: float = 2.0
                      ) -> int:
    """Pick grad-accum steps so remat-stored period inputs + the live
    logits block fit the per-chip activation budget."""
    from repro.models.transformer import n_periods
    b_loc = max(1, shape.global_batch // dp_total)
    periods = n_periods(cfg) if cfg.scan_layers else cfg.n_layers
    per_elem = periods * shape.seq_len * cfg.d_model * 2 / 1e9
    # logits + softmax temps: f32+bf16 ≈ 6 B/entry, sharded 16-way over
    # 'model' (vocab- or seq-sharded; see distributed.shard_logits)
    per_elem += shape.seq_len * cfg.vocab * 6 / 16 / 1e9
    micro = 1
    while micro < b_loc and (b_loc / micro) * per_elem > budget_gb:
        micro *= 2
    return min(micro, b_loc)


def analytic_memory_floor(model, shape, n_chips: int, quant_kv: bool,
                          weights_bits: int = 0) -> float:
    """Lower bound on per-chip HBM traffic per step (bytes): parameters
    actually touched + KV/state cache + gross activation IO. The XLA
    "bytes accessed" metric is an unfused upper bound; the truth on TPU
    lies between — both are reported (§Roofline methodology)."""
    cfg = model.cfg
    p = count_params(model)
    wbytes = (weights_bits / 8.0) if weights_bits else 2.0
    pb = p["total"] * wbytes                 # bf16 or int8/int4 weights
    act_tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind != "decode" else 1)
    act_io = act_tokens * cfg.d_model * cfg.n_layers * 2 * 4
    if shape.kind == "train":
        # fwd + bwd + remat reads of weights, grad writes, fp32 opt states
        total = pb * 3 + p["total"] * 4 + p["total"] * 16 + act_io * 3
    elif shape.kind == "prefill":
        total = pb + act_io
    else:
        kv_bytes_token = 1 if quant_kv else 2
        if cfg.rwkv:
            cache = (cfg.d_model // cfg.rwkv_head_dim) * cfg.rwkv_head_dim \
                ** 2 * 4 * cfg.n_layers * shape.global_batch
        elif cfg.mla is not None:
            cache = (cfg.mla.kv_lora + cfg.mla.rope_dim) * shape.seq_len \
                * shape.global_batch * kv_bytes_token * cfg.n_layers
        else:
            slots = min(shape.seq_len, cfg.window or shape.seq_len)
            n_attn = cfg.n_layers if cfg.block_pattern is None else \
                cfg.n_layers // 8
            cache = 2 * slots * cfg.n_kv_heads * cfg.head_dim \
                * shape.global_batch * kv_bytes_token * n_attn
        active_pb = p["active"] * wbytes
        total = active_pb + cache + act_io
    return total / n_chips


def _batch_shardings(mesh, spec_tree):
    def one(k, leaf):
        logical = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return jax.sharding.NamedSharding(
            mesh, logical_to_mesh(logical, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, spec_tree)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             microbatches: int = 0, quant_kv: bool = False,
             overrides: dict = None, tag: str = "",
             costing: bool = False, depth_periods: int = 0,
             shape_obj=None, weights_bits: int = 0) -> dict:
    """One dry-run cell.

    costing=False → the production program (scan-over-layers, chunked
    mixers, grad-accum): memory_analysis is the HBM-fit proof; collectives
    are loop-count-corrected from the HLO.
    costing=True  → unrolled, unsharded, depth-truncated lowering: XLA
    cost_analysis does not multiply loop trip counts, so flops/bytes are
    measured with every iteration visible. ``depth_periods`` truncates the
    (homogeneous) stack; ``costing_cell`` extrapolates 1→2 periods to the
    full depth (exact for layer-homogeneous models).
    """
    shape = shape_obj or SHAPES[shape_name]
    cfg = get_config(arch)
    if costing:
        over = dict(overrides or {})
        over.setdefault("scan_layers", False)
        over.setdefault("unroll_chunks", True)
        over.setdefault("attn_q_chunk", shape.seq_len)
        over.setdefault("mamba_chunk", shape.seq_len)
        over.setdefault("rwkv_chunk", min(512, shape.seq_len))
        if depth_periods:
            from repro.models.transformer import layer_plan
            plen = len(layer_plan(cfg))
            over["n_layers"] = plen * depth_periods
            if cfg.encoder_layers:
                over["encoder_layers"] = max(1, depth_periods)
        overrides = over
        microbatches = 1
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return {"arch": arch, "shape": shape_name, "status": "skip",
                "mesh": "multipod" if multi_pod else "pod", "tag": tag,
                "reason": "full-attention arch at 512k decode"}
    mesh_tag = "multipod" if multi_pod else "pod"
    debug = bool(os.environ.get("REPRO_DRYRUN_DEBUG_MESH"))
    n_chips = (8 if debug else 512) if multi_pod else (8 if debug else 256)
    if costing:
        # single-device, unsharded: no SPMD pass — totals are exact
        # (unrolled loops) and divide by the production chip count.
        mesh = None
        dp_total = 32 if multi_pod else 16
    elif debug:
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(multi_pod=multi_pod)
        compat.activate_mesh(mesh)
        dp_total = int(np.prod([s for a, s in zip(mesh.axis_names,
                                                  mesh.devices.shape)
                                if a in ("pod", "data")]))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        compat.activate_mesh(mesh)
        dp_total = int(np.prod([s for a, s in zip(mesh.axis_names,
                                                  mesh.devices.shape)
                                if a in ("pod", "data")]))
    model = build_model(cfg)

    params_sh = model.param_shapes()
    if weights_bits:
        from repro.quant.apply import quantized_param_shapes
        params_sh = quantized_param_shapes(params_sh, weights_bits)
    p_shard = make_param_shardings(mesh, params_sh) if mesh else None
    in_spec = model.input_specs(shape)
    b_shard = _batch_shardings(mesh, in_spec) if mesh else None

    t0 = time.time()
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
              "status": "ok", "costing": costing, "n_chips": n_chips,
              "weights_bits": weights_bits, "quant_kv": quant_kv,
              "params": count_params(model),
              "model_flops": model_flops(model, shape), "tag": tag}

    if shape.kind == "train":
        micro = microbatches or auto_microbatches(cfg, shape, dp_total)
        result["microbatches"] = micro
        opt_sh = jax.eval_shape(adamw_init, params_sh)
        o_shard = make_param_shardings(mesh, opt_sh) if mesh else None
        step = make_train_step(
            model, AdamWConfig(), microbatches=micro,
            grad_reduce_dtype=jnp.bfloat16
            if os.environ.get("REPRO_BF16_GRAD_REDUCE") else None)
        jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1)) if mesh else \
            jax.jit(step, donate_argnums=(0, 1))
        lowered = jitted.lower(params_sh, opt_sh, in_spec)
    elif shape.kind == "prefill":
        cache_sh = model.cache_shapes(shape.global_batch, shape.seq_len,
                                      quantize_kv=quant_kv)
        c_shard = make_cache_shardings(mesh, cache_sh) if mesh else None
        jitted = jax.jit(model.prefill,
                         in_shardings=(p_shard, b_shard, c_shard),
                         out_shardings=(None, None),
                         donate_argnums=(2,)) if mesh else \
            jax.jit(model.prefill, donate_argnums=(2,))
        lowered = jitted.lower(params_sh, in_spec, cache_sh)
    else:  # decode
        cache_sh = model.cache_shapes(shape.global_batch, shape.seq_len,
                                      quantize_kv=quant_kv)
        c_shard = make_cache_shardings(mesh, cache_sh) if mesh else None
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        t_shard = _batch_shardings(mesh, tok) if mesh else None
        jitted = jax.jit(model.decode_step,
                         in_shardings=(p_shard, t_shard, c_shard),
                         out_shardings=(None, c_shard),
                         donate_argnums=(2,)) if mesh else \
            jax.jit(model.decode_step, donate_argnums=(2,))
        lowered = jitted.lower(params_sh, tok, cache_sh)

    result["lower_s"] = time.time() - t0
    t1 = time.time()
    compiled = lowered.compile()
    result["compile_s"] = time.time() - t1

    mem = compiled.memory_analysis()
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            result[attr] = int(v)
    arg_b = result.get("argument_size_in_bytes", 0)
    tmp_b = result.get("temp_size_in_bytes", 0)
    out_b = result.get("output_size_in_bytes", 0)
    alias_b = result.get("alias_size_in_bytes", 0)
    result["hbm_per_chip_gb"] = (arg_b + tmp_b + out_b - alias_b) / 1e9
    result["fits_16gb"] = result["hbm_per_chip_gb"] < 16.0

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):      # old jax: one dict per program
        cost = cost[0] if cost else {}
    result["hlo_flops"] = float(cost.get("flops", -1.0))
    result["hlo_bytes"] = float(cost.get("bytes accessed", -1.0))

    hlo = compiled.as_text()
    result["collectives"] = collective_bytes_from_hlo(hlo, loop_aware=True)
    result["hlo_lines"] = hlo.count("\n")

    # roofline terms (seconds per chip per step). For costing artifacts the
    # totals are whole-model (single device): divide by production chips.
    div = n_chips if costing else 1
    coll_b = result["collectives"]["total"]
    flops = max(result["hlo_flops"], 0.0) / div
    hbytes = max(result["hlo_bytes"], 0.0) / div
    result["memory_floor_bytes"] = analytic_memory_floor(
        model, shape, n_chips, quant_kv, weights_bits)
    result["roofline"] = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbytes / HBM_BW,
        "memory_floor_s": result["memory_floor_bytes"] / HBM_BW,
        "collective_s": coll_b / ICI_BW,
        "model_flops_ratio": (result["model_flops"] / n_chips) / flops
        if flops > 0 else None,
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: result["roofline"][k])
    result["roofline"]["dominant"] = dom
    return result


def costing_cell(arch: str, shape_name: str, multi_pod: bool,
                 quant_kv: bool = False, overrides: dict = None,
                 tag: str = "cost") -> dict:
    """Loop-complete flops/bytes by depth extrapolation: lower the unrolled
    model at 1 and 2 periods and extend linearly to the full depth — exact
    for layer-homogeneous stacks (all ten archs)."""
    from repro.models.transformer import n_periods
    cfg_full = get_config(arch)
    if overrides:
        cfg_full = dataclasses.replace(cfg_full, **overrides)
    periods = n_periods(cfg_full)
    shape = SHAPES[shape_name]
    # per-device-scale batch: exact for dense models (flops linear in batch)
    # and faithful for MoE, whose dispatch tensors scale with the *local*
    # token count in the production sharded program.
    dp_total = 32 if multi_pod else 16
    cost_batch = max(1, shape.global_batch // dp_total)
    cost_shape = dataclasses.replace(shape, global_batch=cost_batch)
    batch_scale = shape.global_batch / cost_batch
    r1 = run_cell(arch, shape_name, multi_pod, quant_kv=quant_kv,
                  overrides=overrides, tag=tag, costing=True,
                  depth_periods=1, shape_obj=cost_shape)
    if r1.get("status") != "ok":
        return r1
    if periods > 1:
        r2 = run_cell(arch, shape_name, multi_pod, quant_kv=quant_kv,
                      overrides=overrides, tag=tag, costing=True,
                      depth_periods=2, shape_obj=cost_shape)
        if r2.get("status") != "ok":
            return r2
        f = r1["hlo_flops"] + (periods - 1) * (r2["hlo_flops"]
                                               - r1["hlo_flops"])
        b = r1["hlo_bytes"] + (periods - 1) * (r2["hlo_bytes"]
                                               - r1["hlo_bytes"])
        r1["compile_s"] += r2["compile_s"]
    else:
        f, b = r1["hlo_flops"], r1["hlo_bytes"]
    f *= batch_scale
    b *= batch_scale
    model = build_model(cfg_full)
    n_chips = r1["n_chips"]
    floor = analytic_memory_floor(model, shape, n_chips, quant_kv)
    r1.update({
        "hlo_flops": f, "hlo_bytes": b, "extrapolated_periods": periods,
        "batch_scale": batch_scale,
        "params": count_params(model),
        "model_flops": model_flops(model, shape),
        "memory_floor_bytes": floor,
    })
    r1["roofline"] = {
        "compute_s": f / n_chips / PEAK_FLOPS,
        "memory_s": b / n_chips / HBM_BW,
        "memory_floor_s": floor / HBM_BW,
        "collective_s": 0.0,       # costing is unsharded; see prod artifact
        "model_flops_ratio": (r1["model_flops"] / n_chips)
        / (f / n_chips) if f > 0 else None,
    }
    r1["roofline"]["dominant"] = ("compute_s"
                                  if r1["roofline"]["compute_s"]
                                  >= r1["roofline"]["memory_s"]
                                  else "memory_s")
    # drop misleading memory numbers (unrolled + no sharding)
    for k in ("hbm_per_chip_gb", "fits_16gb"):
        r1.pop(k, None)
    return r1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list_archs() + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--quant-kv", action="store_true")
    ap.add_argument("--costing", action="store_true",
                    help="unrolled lowering for exact flops/collectives")
    ap.add_argument("--weights-bits", type=int, default=0,
                    choices=[0, 4, 8],
                    help="serve with int8/int4 SQuant weights (decode/"
                         "prefill cells)")
    ap.add_argument("--mla-absorb", action="store_true",
                    help="decode-time MLA weight absorption (minicpm3)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=ART_DIR)
    args = ap.parse_args()
    if args.costing and not args.tag:
        args.tag = "cost"

    os.makedirs(args.out, exist_ok=True)
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    for arch in archs:
        for shape in shapes:
            for m in meshes:
                name = f"{arch}__{shape}__{m}"
                if args.tag:
                    name += f"__{args.tag}"
                path = os.path.join(args.out, name + ".json")
                print(f"=== {name} ===", flush=True)
                overrides = None
                if args.mla_absorb:
                    cfg0 = get_config(arch)
                    if cfg0.mla is not None:
                        overrides = {"mla": dataclasses.replace(
                            cfg0.mla, absorb=True)}
                try:
                    if args.costing:
                        res = costing_cell(arch, shape, m == "multipod",
                                           args.quant_kv, tag=args.tag,
                                           overrides=overrides)
                    else:
                        res = run_cell(arch, shape, m == "multipod",
                                       args.microbatches, args.quant_kv,
                                       tag=args.tag, overrides=overrides,
                                       weights_bits=args.weights_bits)
                except Exception as e:  # noqa: BLE001 — record and continue
                    res = {"arch": arch, "shape": shape, "mesh": m,
                           "status": "error", "error": repr(e)[:2000],
                           "tag": args.tag}
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                status = res["status"]
                if status == "ok":
                    r = res["roofline"]
                    hbm = res.get("hbm_per_chip_gb")
                    hbm_s = f"hbm/chip={hbm:.2f}GB " if hbm is not None \
                        else ""
                    print(f"  ok compile={res['compile_s']:.1f}s {hbm_s}"
                          f"compute={r['compute_s']*1e3:.2f}ms "
                          f"memory={r['memory_s']*1e3:.2f}ms "
                          f"coll={r['collective_s']*1e3:.2f}ms "
                          f"dom={r['dominant']}", flush=True)
                else:
                    print(f"  {status}: {res.get('reason', res.get('error'))}",
                          flush=True)


if __name__ == "__main__":
    main()
