"""Production mesh factories.

Functions, not module-level constants — importing this module never touches
jax device state (required: the dry-run overrides the device count before
any jax initialization, and smoke tests must keep seeing one device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The assigned production mesh: 16×16 per pod, 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(*, multi_pod: bool = False):
    """Scaled-down mesh (8 devices) with the production topology, for CI
    subprocess tests."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
