"""Production mesh factories.

Functions, not module-level constants — importing this module never touches
jax device state (required: the dry-run overrides the device count before
any jax initialization, and smoke tests must keep seeing one device).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.distributed import compat


def make_production_mesh(*, multi_pod: bool = False):
    """The assigned production mesh: 16×16 per pod, 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Scaled-down mesh (8 devices) with the production topology, for CI
    subprocess tests."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_quantize_mesh(n_devices: Optional[int] = None):
    """1-axis 'data' mesh for sharded quantization (``quantize_tree(mesh=)``).

    SQuant's flip objective is row-independent, so quantization parallelism
    is pure row DP: one flat 'data' axis over however many devices the host
    sees (or the first ``n_devices`` of them).
    """
    devices = jax.devices()
    n = len(devices) if n_devices is None else n_devices
    if not 1 <= n <= len(devices):
        raise ValueError(f"requested {n} devices, host has {len(devices)}")
    return compat.make_mesh((n,), ("data",), devices=devices[:n])
