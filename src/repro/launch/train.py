"""Training CLI.

Container scale: reduced configs train for real on CPU (synthetic/Markov
data) with the full fault-tolerance path (checkpoint/restart, straggler
monitor). Production scale: the same step lowered in launch/dryrun.py runs
unchanged on a real mesh — pass --production to build the 16×16(-per-pod)
mesh and shard params/opt/data with the framework rules.

Examples:
    python -m repro.launch.train --arch granite-3-8b --reduced --steps 200
    python -m repro.launch.train --arch mixtral-8x7b --reduced --steps 100 \
        --pod-compress   # int8 cross-pod gradient all-reduce (needs pods)
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.data.synthetic import markov_batches, synthetic_batches
from repro.models.model import build_model
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import Trainer, TrainerConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--data", default="markov", choices=["markov", "random"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--pod-compress", action="store_true")
    ap.add_argument("--production", action="store_true",
                    help="build the production mesh (needs ≥256 devices)")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.reduced:
        cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    mesh = None
    if args.production:
        from repro.distributed import compat
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.pod_compress)
        compat.activate_mesh(mesh)

    ocfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                       decay_steps=args.steps)
    step = jax.jit(make_train_step(model, ocfg,
                                   microbatches=args.microbatches,
                                   pod_compress=args.pod_compress,
                                   mesh=mesh))
    trainer = Trainer(model, ocfg,
                      TrainerConfig(total_steps=args.steps,
                                    checkpoint_every=args.ckpt_every,
                                    checkpoint_dir=args.ckpt_dir),
                      train_step=step)
    gen = markov_batches if args.data == "markov" else synthetic_batches
    extra = {}
    if cfg.is_encdec:
        extra = {"encdec_dim": cfg.d_model, "enc_ratio": cfg.enc_ratio}
    it = (jax.tree_util.tree_map(jnp.asarray, b)
          for b in gen(args.batch, args.seq, cfg.vocab, seed=0, **extra))
    params, opt, info = trainer.run(params, it)
    hist = info["history"]
    print(f"[train] done: loss {hist[0]:.4f} → {hist[-1]:.4f} "
          f"({len(hist)} steps, {len(info['stragglers'])} stragglers)")


if __name__ == "__main__":
    main()
