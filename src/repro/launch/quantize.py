"""Data-free quantization CLI: checkpoint in, SQuant-ed checkpoint out.

The black-box post-processing deployment mode the paper argues for: no data,
no back-prop, sub-second per network.

Example:
    python -m repro.launch.quantize --arch granite-3-8b --reduced \
        --method squant --bits 4 --out /tmp/granite_w4
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config, list_archs
from repro.core.dispatch import BACKENDS
from repro.core.pipeline import quantize_tree
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint dir (default: fresh init)")
    ap.add_argument("--method", default="squant",
                    choices=["rtn", "squant", "squant_e", "squant_ek",
                             "squant_ec"])
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=128)
    ap.add_argument("--backend", default="auto", choices=list(BACKENDS),
                    help="kernel backend: auto (TPU→pallas, CPU→ref), ref "
                         "(jnp), pallas (compiled TPU kernel), interpret "
                         "(kernel body on CPU, for validation)")
    ap.add_argument("--serial", action="store_true",
                    help="legacy per-layer loop with one device sync per "
                         "layer (baseline for the batched pipeline)")
    ap.add_argument("--mesh", default="off",
                    help="sharded quantization: 'off' (default), 'auto' "
                         "(1-axis 'data' mesh over every host device), or an "
                         "integer device count. Row-partitions each bucket "
                         "under shard_map; bit-identical to the unsharded "
                         "path")
    ap.add_argument("--out", default="/tmp/repro_quantized")
    ap.add_argument("--serving-ckpt", default=None, metavar="DIR",
                    help="additionally write a *native* quantized serving "
                         "checkpoint (w_q/w_q4+w_scale qdict tree, int4 "
                         "kept packed on disk, quant metadata in "
                         "index.json) that repro.launch.serve "
                         "--reload-from hot-loads without re-quantizing")
    ap.add_argument("--serving-step", type=int, default=0,
                    help="step number for --serving-ckpt (watchers reload "
                         "steps in increasing order)")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.reduced:
        cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    if args.ckpt:
        ck = Checkpointer(args.ckpt)
        params, _, step = ck.restore_latest()
        print(f"[quantize] loaded step {step} from {args.ckpt}")
    else:
        params = model.init(jax.random.PRNGKey(0))

    mesh = None
    if args.mesh != "off":
        from repro.launch.mesh import make_quantize_mesh
        mesh = make_quantize_mesh(None if args.mesh == "auto"
                                  else int(args.mesh))
        print(f"[quantize] sharding rows over mesh {dict(mesh.shape)}")

    qtree, report = quantize_tree(params, method=args.method, bits=args.bits,
                                  group_size=args.group_size,
                                  dequantize=True, backend=args.backend,
                                  batched=not args.serial, mesh=mesh)
    print(f"[quantize] {report.summary()}")
    os.makedirs(args.out, exist_ok=True)
    Checkpointer(args.out, async_save=False).save(0, qtree, {"step": 0})
    with open(os.path.join(args.out, "quant_report.json"), "w") as f:
        json.dump({"method": args.method, "bits": args.bits,
                   "backend": report.backend,
                   "batched": not args.serial,
                   "total_ms": report.total_millis,
                   "dispatch_ms": report.dispatch_millis,
                   "sync_ms": report.sync_millis,
                   "mesh_axis": report.mesh_axis,
                   "mesh_size": report.mesh_size,
                   "shards": [{"device": s.device, "rows": s.rows,
                               "pad_rows": s.pad_rows}
                              for s in report.shards],
                   "buckets": [{"key": b.key, "layers": b.num_layers,
                                "ms": b.dispatch_millis}
                               for b in report.buckets],
                   "layers": [{"path": l.path, "shape": list(l.shape),
                               "ms": l.millis, "bucket": l.bucket}
                              for l in report.layers]},
                  f, indent=1)
    print(f"[quantize] wrote {args.out}")

    if args.serving_ckpt:
        # the serving checkpoint needs the qdict layout (stack dims kept,
        # plain shardable arrays), which only the serving-format quantizer
        # emits — a separate pass from the pipeline run above, so its
        # metadata records this pass's own timing rather than the batched
        # run's backend/mesh digest.
        from repro.quant.apply import quantize_params_serving
        qserve, meta = quantize_params_serving(params, args.bits,
                                               method=args.method,
                                               group_size=args.group_size)
        Checkpointer(args.serving_ckpt, async_save=False).save_serving(
            args.serving_step, qserve, quant_meta=meta)
        print(f"[quantize] wrote serving checkpoint step "
              f"{args.serving_step} → {args.serving_ckpt} "
              f"({meta['leaf_format']})")


if __name__ == "__main__":
    main()
