"""Serving CLI: on-the-fly data-free quantization + batched generation.

Example:
    python -m repro.launch.serve --arch granite-3-8b --reduced \
        --quantize squant --bits 8 --prompts "hello" "world"
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_config, list_archs
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.serving.engine import Request, ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--quantize", default=None,
                    choices=[None, "rtn", "squant", "squant_e", "squant_ek",
                             "squant_ec"])
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--quant-kv", action="store_true")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompts", nargs="*", default=["hello world"])
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    cfg = dataclasses.replace(cfg, dtype="float32",
                              vocab=max(cfg.vocab, 260))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = ByteTokenizer()
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=args.batch, max_len=256,
                                  quantize_weights=args.quantize,
                                  weight_bits=args.bits,
                                  quantize_kv=args.quant_kv))
    if eng.quant_report:
        print("[serve]", eng.quant_report.summary())
    reqs = [Request(prompt=tok.encode(p), max_new_tokens=args.max_new,
                    request_id=i) for i, p in enumerate(args.prompts)]
    for c in eng.generate(reqs):
        print(f"[serve] req {c.request_id}: {c.tokens} "
              f"(prefill {c.prefill_ms:.1f} ms, decode {c.decode_ms:.1f} ms)")


if __name__ == "__main__":
    main()
