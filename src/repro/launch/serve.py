"""Serving CLI: on-the-fly data-free quantization + batched generation,
with optional zero-downtime weight reloads from a checkpoint directory.

Example:
    python -m repro.launch.serve --arch granite-3-8b --reduced \
        --quantize squant --bits 8 --prompts "hello" "world"

Hot reload: watch a checkpoint dir (the trainer's, or one written by
``repro.launch.quantize --serving-ckpt``) and swap new COMMITTED steps in
between decode rounds — fp steps are re-quantized on the fly (sub-second,
data-free: the point of SQuant), quantized steps load natively:

    python -m repro.launch.serve --quantize squant --bits 8 \
        --reload-from /tmp/ckpts --reload-poll 0.5 --rounds 20

Continuous batching (``--scheduler continuous``): a fixed pool of
``--max-slots`` decode slots over one persistent KV cache — short requests
retire immediately and queued ones refill mid-stream, and a staged reload
drains admission and swaps at a step boundary (force-swap after
``--swap-deadline-ms`` instead of waiting for the longest request):

    python -m repro.launch.serve --scheduler continuous --max-slots 8 \
        --quantize squant --bits 8 --reload-from /tmp/ckpts

Paged KV cache (``--kv-backend paged``): block-pool KV with per-slot block
tables, shared-prefix reuse and copy-on-write — many requests carrying the
same system prompt prefill it once:

    python -m repro.launch.serve --scheduler continuous --max-slots 8 \
        --kv-backend paged --block-size 16 --prompts "hi" "hi there"

Paged composes with chunked admission (``--prefill-chunk``): each pending
prefills its own unshared suffix a bounded chunk per step at its own
position (no shared clock, so any chunk size works mid-flight), keeping
resident decode tails flat while long shared-prefix prompts admit:

    python -m repro.launch.serve --scheduler continuous --max-slots 8 \
        --kv-backend paged --block-size 16 --prefill-chunk 16

Quantized KV (``--quant-kv``) composes with both: the paged pool stores
int8 codes plus per-(position, head) scales (~0.27x fp32 bytes/position at
full widths) and decode runs the fused dequant-attention kernel. Tokens
are tolerance-equivalent, not bit-identical — pass ``--verify-agreement``
to measure teacher-forced greedy agreement against an fp-KV oracle engine
(the per-config budget is 0.98, see ``repro.serving.equivalence``):

    python -m repro.launch.serve --scheduler continuous --max-slots 8 \
        --kv-backend paged --quant-kv --prefill-chunk 16 --verify-agreement

Self-speculative decoding (``--speculative``): a ``--draft-bits``
quantization of the SAME checkpoint autoregressively proposes
``--draft-k``-token runs per slot, the serving tree verifies all
positions in one batched forward, and the longest matching prefix is
accepted. Greedy acceptance keeps tokens bit-identical to verifier-only
decode; the run ends with the draft acceptance printout. Requires the
paged backend and greedy sampling (not --quant-kv):

    python -m repro.launch.serve --scheduler continuous --max-slots 8 \
        --kv-backend paged --quantize squant --bits 8 \
        --speculative --draft-bits 4 --draft-k 4
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_config, list_archs
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.serving.engine import Request, ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--quantize", default=None,
                    choices=[None, "rtn", "squant", "squant_e", "squant_ek",
                             "squant_ec"])
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--quant-kv", action="store_true",
                    help="int8 KV cache with per-(position, head) scales; "
                         "composes with --kv-backend paged (fused dequant "
                         "decode kernel) and --prefill-chunk")
    ap.add_argument("--verify-agreement", action="store_true",
                    help="continuous + --quant-kv: after serving, replay "
                         "the prompts teacher-forced against an fp-KV "
                         "oracle engine and report greedy-token agreement "
                         "(budget 0.98 at production widths)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--scheduler", default="round",
                    choices=["round", "continuous"],
                    help="round: static batches, swap between rounds; "
                         "continuous: slot pool with per-request "
                         "admission/retirement and reload-aware drain")
    ap.add_argument("--max-slots", type=int, default=0,
                    help="continuous decode-slot pool size (0: --batch)")
    ap.add_argument("--swap-deadline-ms", type=float, default=250.0,
                    help="continuous: max ms to drain in-flight slots "
                         "before a staged reload is force-swapped "
                         "(negative: drain fully, never force)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="continuous: consume admission prefills at most "
                         "this many prompt positions per engine step while "
                         "resident slots keep decoding, bounding the "
                         "step-time spike a long-prompt admission causes "
                         "(0: monolithic prefill; composes with "
                         "--kv-backend paged)")
    ap.add_argument("--kv-backend", default="contiguous",
                    choices=["contiguous", "paged"],
                    help="KV-cache layout: contiguous (one cache row per "
                         "slot) or paged (continuous only: block pool + "
                         "per-slot block tables with shared-prefix reuse "
                         "and copy-on-write — repeated system prompts "
                         "prefill once)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged: positions per KV block (must divide "
                         "max_len)")
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="paged: physical blocks in the pool incl. the "
                         "trash block (0: full capacity, no admission "
                         "backpressure)")
    ap.add_argument("--speculative", action="store_true",
                    help="self-speculative decoding (paged + continuous + "
                         "greedy): a --draft-bits quantization of the same "
                         "checkpoint drafts --draft-k-token runs, the "
                         "serving tree verifies them in one batched "
                         "forward; tokens stay bit-identical to "
                         "verifier-only decode")
    ap.add_argument("--draft-bits", type=int, default=4,
                    help="speculative: bit-width of the drafter "
                         "quantization (the verifier serves at --bits)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="speculative: draft tokens proposed per verify "
                         "cycle")
    ap.add_argument("--prompts", nargs="*", default=["hello world"])
    ap.add_argument("--reload-from", default=None, metavar="CKPT_DIR",
                    help="watch this checkpoint dir and hot-swap new "
                         "COMMITTED steps at decode-round boundaries")
    ap.add_argument("--reload-poll", type=float, default=1.0,
                    help="watcher poll interval in seconds")
    ap.add_argument("--rounds", type=int, default=1,
                    help="generation passes over the prompts (use >1 with "
                         "--reload-from to observe live swaps)")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    cfg = dataclasses.replace(cfg, dtype="float32",
                              vocab=max(cfg.vocab, 260))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = ByteTokenizer()
    deadline = None if args.swap_deadline_ms < 0 else args.swap_deadline_ms
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=args.batch, max_len=256,
                                  quantize_weights=args.quantize,
                                  weight_bits=args.bits,
                                  quantize_kv=args.quant_kv,
                                  scheduler=args.scheduler,
                                  max_slots=args.max_slots,
                                  swap_deadline_ms=deadline,
                                  prefill_chunk=args.prefill_chunk,
                                  kv_backend=args.kv_backend,
                                  block_size=args.block_size,
                                  kv_blocks=args.kv_blocks,
                                  speculative=args.speculative,
                                  draft_bits=args.draft_bits,
                                  draft_k=args.draft_k))
    if eng.quant_report:
        print("[serve]", eng.quant_report.summary())
    if args.reload_from:
        eng.watch_checkpoints(args.reload_from, poll_s=args.reload_poll)
        print(f"[serve] watching {args.reload_from} "
              f"(poll {args.reload_poll}s)")
    reqs = [Request(prompt=tok.encode(p), max_new_tokens=args.max_new,
                    request_id=i) for i, p in enumerate(args.prompts)]
    for rnd in range(args.rounds):
        for c in eng.generate(reqs):
            print(f"[serve] round {rnd} req {c.request_id} "
                  f"v{c.weights_version}: {c.tokens} "
                  f"(prefill {c.prefill_ms:.1f} ms, decode "
                  f"{c.decode_ms:.1f} ms, swap {c.swap_ms:.2f} ms)")
    stats = eng.stats()
    w = stats["weights"]
    sch = stats["scheduler"]
    print(f"[serve] scheduler={sch['kind']} steps={sch['steps']}, "
          f"weights v{w['version']} (source {w['source']}, "
          f"{w['swaps']} swaps, {w['versions_built']} versions built)")
    if sch["kind"] == "continuous":
        print(f"[serve] slots={sch['max_slots']} admitted={sch['admitted']} "
              f"waves={sch['waves']} drains={sch['drains']} "
              f"forced_swaps={sch['forced_swaps']} "
              f"mean_occupancy={sch['mean_occupancy']:.2f}")
        if sch["step_ms"]:
            print(f"[serve] step-time p50/p95/p99 = "
                  f"{sch['step_ms']['p50']:.1f}/{sch['step_ms']['p95']:.1f}/"
                  f"{sch['step_ms']['p99']:.1f} ms "
                  f"(prefill_chunk={sch['prefill_chunk']}, "
                  f"{sch['chunk_steps']} chunk forwards, "
                  f"{sch['pendings_abandoned']} abandoned)")
        kv = sch["kv"]
        if kv.get("backend") == "paged":
            print(f"[serve] paged kv: {kv['blocks_total']} blocks x "
                  f"{kv['block_size']} (peak {kv['peak_blocks_active']} "
                  f"active), prefix hits={kv['prefix_hits']} "
                  f"({kv['prefix_tokens_reused']} tokens reused), "
                  f"cow={kv['cow_copies']} evictions={kv['evictions']}")
            print(f"[serve] kv pool: "
                  f"{'int8+scales' if kv['quantize_kv'] else 'fp'} "
                  f"{kv['pool_bytes'] / 1e6:.2f} MB "
                  f"({kv['bytes_per_position']} B/position)")
        if sch["speculative"]:
            al = sch["accepted_len"]
            print(f"[serve] speculative: {sch['spec_cycles']} verify "
                  f"cycles, {sch['draft_tokens_accepted']}/"
                  f"{sch['draft_tokens_proposed']} drafts accepted "
                  f"(rate {sch['acceptance_rate']:.2f}), accepted-len "
                  f"p50/p95 = {al.get('p50', 0.0):.1f}/"
                  f"{al.get('p95', 0.0):.1f} tokens/cycle")
    if args.verify_agreement:
        if args.scheduler != "continuous" or not args.quant_kv:
            print("[serve] --verify-agreement needs --scheduler continuous "
                  "and --quant-kv; skipping")
        else:
            from repro.serving.equivalence import (agreement_budget,
                                                   greedy_token_agreement,
                                                   oracle_tokens)
            oracle_eng = ServeEngine(
                model, params,
                dataclasses.replace(eng.cfg, quantize_kv=False))
            oracle = oracle_tokens(oracle_eng.generate(reqs))
            oracle_eng.close()
            rep = greedy_token_agreement(eng, reqs, oracle)
            budget = agreement_budget(eng.cfg, eng.model.cfg)
            print(f"[serve] greedy agreement vs fp-KV oracle: "
                  f"{rep.rate:.4f} ({rep.matched}/{rep.compared} tokens, "
                  f"budget {budget:.2f} at production widths)")
    for err in w["errors"]:
        print(f"[serve] reload error: {err}")
    eng.close()


if __name__ == "__main__":
    main()
