"""Quantized tensor container + int4 packing + quantization-run reports.

A ``QuantizedTensor`` is a pytree holding integer codes plus dequantization
scales. It is the on-disk / in-memory serving format produced by every
quantizer in this framework (SQuant and the baselines alike). The report
dataclasses at the bottom (``QuantReport`` and friends) are the wall-time /
dispatch / shard accounting emitted by ``core.pipeline.quantize_tree`` and
consumed by the launch CLIs and benchmarks.

Conventions
-----------
* Codes are symmetric signed integers in ``[-qmax, qmax]`` with
  ``qmax = 2**(bits-1) - 1`` (paper's uniform symmetric grid).
* ``scale`` broadcasts against the *output-channel* (row) dimension:
  per-channel scale has shape ``(M, 1)``; per-group ``(M, G_count)`` where the
  code tensor is logically ``(M, G_count, group_size)``.
* 4-bit codes are stored packed two-per-byte in an int8 carrier
  (little-nibble-first) to honour the real memory footprint.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def qmax_for_bits(bits: int) -> int:
    if not 2 <= bits <= 8:
        raise ValueError(f"bits must be in [2, 8], got {bits}")
    return 2 ** (bits - 1) - 1


@jax.jit
def _pack_int4_jit(codes: jax.Array) -> jax.Array:
    lo = codes[..., 0::2].astype(jnp.int8)
    hi = codes[..., 1::2].astype(jnp.int8)
    return ((hi << 4) | (lo & 0x0F)).astype(jnp.int8)


def pack_int4(codes: jax.Array) -> jax.Array:
    """Pack int8 codes in [-8, 7] into int8 bytes, two nibbles per byte.

    Last dim must be even. Little-nibble-first: out[..., i] holds codes
    (2i) in bits 0-3 and (2i+1) in bits 4-7. Jitted: the strided slices are
    gather ops that dominate quantization wall time when run eagerly.
    """
    if codes.shape[-1] % 2 != 0:
        raise ValueError(f"last dim must be even, got {codes.shape}")
    return _pack_int4_jit(codes)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4`; returns sign-extended int8 codes."""
    lo = (packed << 4).astype(jnp.int8) >> 4  # arithmetic shift sign-extends
    hi = packed >> 4
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Integer codes + scales. ``data`` is int8 (packed when bits==4)."""

    data: jax.Array           # int8; (M, N) or (M, N//2) when packed
    scale: jax.Array          # f32; broadcastable to (M, groups)
    bits: int = 8
    group_size: Optional[int] = None   # None → per-channel scale
    shape: tuple = ()                  # logical (unpacked) shape

    def tree_flatten(self):
        return (self.data, self.scale), (self.bits, self.group_size, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, scale = children
        bits, group_size, shape = aux
        return cls(data=data, scale=scale, bits=bits, group_size=group_size,
                   shape=shape)

    @property
    def packed(self) -> bool:
        return self.bits <= 4

    def codes(self) -> jax.Array:
        """Unpacked int8 codes with logical shape."""
        n = int(np.prod(self.shape[1:]))
        if self.packed:
            flat = unpack_int4(self.data).reshape(self.shape[0], -1)
            return flat[:, :n].reshape(self.shape)
        return self.data.reshape(self.shape)

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        c = self.codes().astype(jnp.float32)
        m = self.shape[0]
        rest = int(np.prod(self.shape[1:]))
        if self.group_size is None:
            w = c.reshape(m, rest) * self.scale.reshape(m, 1)
        else:
            g = self.group_size
            ngroups = rest // g
            w = (c.reshape(m, ngroups, g)
                 * self.scale.reshape(m, ngroups, 1)).reshape(m, rest)
        return w.reshape(self.shape).astype(dtype)

    def nbytes(self) -> int:
        """True serving footprint in bytes (codes + scales)."""
        return int(np.prod(self.data.shape)) + 4 * int(np.prod(self.scale.shape))

    def with_placement(self, data_sharding, scale_sharding
                       ) -> "QuantizedTensor":
        """The same tensor with codes/scales placed on the given shardings
        (asynchronous ``device_put`` — no host sync)."""
        return QuantizedTensor(
            data=jax.device_put(self.data, data_sharding),
            scale=jax.device_put(self.scale, scale_sharding),
            bits=self.bits, group_size=self.group_size, shape=self.shape)


# ---------------------------------------------------------------------------
# Quantization-run reports (filled by core.pipeline.quantize_tree)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LayerReport:
    path: str
    shape: Tuple[int, ...]
    millis: float              # batched mode: amortized bucket dispatch time
    method: str
    bits: int
    bucket: str = ""           # bucket key this layer was quantized in


@dataclasses.dataclass
class BucketReport:
    key: str                   # "(M, N)xB dtype gG"
    num_layers: int
    dispatch_millis: float     # host time to stack + dispatch this bucket


@dataclasses.dataclass
class ShardReport:
    """Per-device row accounting for the sharded (``mesh=``) pipeline."""
    device: int                # position along the sharded mesh axis
    rows: int                  # real weight rows quantized on this device
    pad_rows: int              # padding rows added so the axis divides


@dataclasses.dataclass
class QuantReport:
    layers: List[LayerReport]
    total_millis: float
    method: str
    bits: int
    backend: str = "ref"
    dispatch_millis: float = 0.0
    sync_millis: float = 0.0
    buckets: List[BucketReport] = dataclasses.field(default_factory=list)
    mesh_axis: str = ""        # sharded runs: name of the partitioned axis
    mesh_size: int = 1         # devices along that axis (1 → unsharded)
    shards: List[ShardReport] = dataclasses.field(default_factory=list)

    def summary(self) -> str:
        s = (f"{self.method} w{self.bits}: {len(self.layers)} layers in "
             f"{self.total_millis:.1f} ms "
             f"({self.total_millis / max(len(self.layers), 1):.2f} ms/layer)")
        if self.buckets:
            s += (f" [{len(self.buckets)} buckets, backend={self.backend}, "
                  f"dispatch {self.dispatch_millis:.1f} ms + "
                  f"sync {self.sync_millis:.1f} ms]")
        if self.mesh_size > 1:
            rows = sum(sh.rows for sh in self.shards)
            s += (f" [sharded {self.mesh_axis}={self.mesh_size}, "
                  f"{rows} rows]")
        return s


def from_codes(codes: jax.Array, scale: jax.Array, bits: int,
               group_size: Optional[int] = None) -> QuantizedTensor:
    """Build a QuantizedTensor from unpacked integer codes."""
    shape = tuple(codes.shape)
    m = shape[0]
    flat = codes.reshape(m, -1).astype(jnp.int8)
    if bits <= 4:
        if flat.shape[-1] % 2:
            flat = jnp.pad(flat, ((0, 0), (0, 1)))
        data = pack_int4(flat)
    else:
        data = flat
    return QuantizedTensor(data=data, scale=scale.astype(jnp.float32),
                           bits=bits, group_size=group_size, shape=shape)
