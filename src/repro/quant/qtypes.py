"""Quantized tensor container + int4 packing.

A ``QuantizedTensor`` is a pytree holding integer codes plus dequantization
scales. It is the on-disk / in-memory serving format produced by every
quantizer in this framework (SQuant and the baselines alike).

Conventions
-----------
* Codes are symmetric signed integers in ``[-qmax, qmax]`` with
  ``qmax = 2**(bits-1) - 1`` (paper's uniform symmetric grid).
* ``scale`` broadcasts against the *output-channel* (row) dimension:
  per-channel scale has shape ``(M, 1)``; per-group ``(M, G_count)`` where the
  code tensor is logically ``(M, G_count, group_size)``.
* 4-bit codes are stored packed two-per-byte in an int8 carrier
  (little-nibble-first) to honour the real memory footprint.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def qmax_for_bits(bits: int) -> int:
    if not 2 <= bits <= 8:
        raise ValueError(f"bits must be in [2, 8], got {bits}")
    return 2 ** (bits - 1) - 1


@jax.jit
def _pack_int4_jit(codes: jax.Array) -> jax.Array:
    lo = codes[..., 0::2].astype(jnp.int8)
    hi = codes[..., 1::2].astype(jnp.int8)
    return ((hi << 4) | (lo & 0x0F)).astype(jnp.int8)


def pack_int4(codes: jax.Array) -> jax.Array:
    """Pack int8 codes in [-8, 7] into int8 bytes, two nibbles per byte.

    Last dim must be even. Little-nibble-first: out[..., i] holds codes
    (2i) in bits 0-3 and (2i+1) in bits 4-7. Jitted: the strided slices are
    gather ops that dominate quantization wall time when run eagerly.
    """
    if codes.shape[-1] % 2 != 0:
        raise ValueError(f"last dim must be even, got {codes.shape}")
    return _pack_int4_jit(codes)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4`; returns sign-extended int8 codes."""
    lo = (packed << 4).astype(jnp.int8) >> 4  # arithmetic shift sign-extends
    hi = packed >> 4
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Integer codes + scales. ``data`` is int8 (packed when bits==4)."""

    data: jax.Array           # int8; (M, N) or (M, N//2) when packed
    scale: jax.Array          # f32; broadcastable to (M, groups)
    bits: int = 8
    group_size: Optional[int] = None   # None → per-channel scale
    shape: tuple = ()                  # logical (unpacked) shape

    def tree_flatten(self):
        return (self.data, self.scale), (self.bits, self.group_size, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, scale = children
        bits, group_size, shape = aux
        return cls(data=data, scale=scale, bits=bits, group_size=group_size,
                   shape=shape)

    @property
    def packed(self) -> bool:
        return self.bits <= 4

    def codes(self) -> jax.Array:
        """Unpacked int8 codes with logical shape."""
        n = int(np.prod(self.shape[1:]))
        if self.packed:
            flat = unpack_int4(self.data).reshape(self.shape[0], -1)
            return flat[:, :n].reshape(self.shape)
        return self.data.reshape(self.shape)

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        c = self.codes().astype(jnp.float32)
        m = self.shape[0]
        rest = int(np.prod(self.shape[1:]))
        if self.group_size is None:
            w = c.reshape(m, rest) * self.scale.reshape(m, 1)
        else:
            g = self.group_size
            ngroups = rest // g
            w = (c.reshape(m, ngroups, g)
                 * self.scale.reshape(m, ngroups, 1)).reshape(m, rest)
        return w.reshape(self.shape).astype(dtype)

    def nbytes(self) -> int:
        """True serving footprint in bytes (codes + scales)."""
        return int(np.prod(self.data.shape)) + 4 * int(np.prod(self.scale.shape))


def from_codes(codes: jax.Array, scale: jax.Array, bits: int,
               group_size: Optional[int] = None) -> QuantizedTensor:
    """Build a QuantizedTensor from unpacked integer codes."""
    shape = tuple(codes.shape)
    m = shape[0]
    flat = codes.reshape(m, -1).astype(jnp.int8)
    if bits <= 4:
        if flat.shape[-1] % 2:
            flat = jnp.pad(flat, ((0, 0), (0, 1)))
        data = pack_int4(flat)
    else:
        data = flat
    return QuantizedTensor(data=data, scale=scale.astype(jnp.float32),
                           bits=bits, group_size=group_size, shape=shape)
