"""Quantized-weights serving format for the *distributed* model.

``QuantizedTensor`` (core pipeline output) is a single-host container; the
sharded serving path instead stores each kernel as two plain arrays living
in the params pytree —

    {"w": (in, out) bf16}  →  {"w_q":  (out, in)  int8      [w8]
                               "w_q4": (out, in/2) int8 packed [w4]
                               "w_scale": (out, 1) f32}

— so GSPMD shards them like any parameter (transposed kernel rules) and
``lax.scan`` over stacked layers still works. ``layers.linear`` and
``moe._expert_matmul`` consume this format directly (dequant-on-the-fly; the
Pallas dequant_matmul kernel is the TPU fast path).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import is_quantizable
from repro.core.squant import SQuantConfig, squant_codes
from repro.quant.qtypes import pack_int4, qmax_for_bits
from repro.quant.scales import compute_scale


def _is_sds(x) -> bool:
    return isinstance(x, jax.ShapeDtypeStruct)


def _qdict_shapes(leaf, bits: int):
    """Shape stand-ins for one quantized kernel (stack dims preserved)."""
    *stack, d_in, d_out = leaf.shape
    key = "w_q4" if bits <= 4 else "w_q"
    qshape = tuple(stack) + ((d_out, d_in // 2) if bits <= 4
                             else (d_out, d_in))
    return {key: jax.ShapeDtypeStruct(qshape, jnp.int8),
            "w_scale": jax.ShapeDtypeStruct(tuple(stack) + (d_out, 1),
                                            jnp.float32)}


def _quantize_leaf(leaf: jnp.ndarray, bits: int, method: str,
                   group_size: Optional[int]):
    """Real quantization of one (possibly stacked) (in, out) kernel."""
    *stack, d_in, d_out = leaf.shape
    w2d = jnp.moveaxis(leaf.reshape(-1, d_in, d_out), -1, -2) \
        .reshape(-1, d_in)                       # (stack*out, in)
    scale = compute_scale(w2d, bits, "max")
    if method == "rtn":
        qmax = qmax_for_bits(bits)
        codes = jnp.clip(jnp.round(w2d / scale), -qmax, qmax)
    else:
        codes, _, _ = squant_codes(w2d, scale, bits=bits,
                                   group_size=group_size, enable_k=True,
                                   enable_c=True)
    codes = codes.astype(jnp.int8)
    if bits <= 4:
        data = pack_int4(codes).reshape(tuple(stack) + (d_out, d_in // 2))
        key = "w_q4"
    else:
        data = codes.reshape(tuple(stack) + (d_out, d_in))
        key = "w_q"
    return {key: data,
            "w_scale": scale.reshape(tuple(stack) + (d_out, 1))}


def _walk(node, path, bits, method, group_size, shapes_only):
    if isinstance(node, dict):
        out = {}
        for k, v in node.items():
            if (k == "w" and isinstance(v, dict) is False
                    and hasattr(v, "shape") and len(v.shape) >= 2
                    and "router" not in path
                    and "embedding" not in path):
                if shapes_only or _is_sds(v):
                    out.update(_qdict_shapes(v, bits))
                else:
                    out.update(_quantize_leaf(v, bits, method, group_size))
            else:
                out[k] = _walk(v, path + (k,), bits, method, group_size,
                               shapes_only)
        return out
    if isinstance(node, list):
        return [_walk(v, path + (str(i),), bits, method, group_size,
                      shapes_only) for i, v in enumerate(node)]
    return node


def quantized_param_shapes(params_shape: Any, bits: int) -> Any:
    """ShapeDtypeStruct tree for the quantized serving format."""
    return _walk(params_shape, (), bits, "squant", None, True)


def quantize_params_sharded(params: Any, bits: int, method: str = "squant",
                            group_size: Optional[int] = 128) -> Any:
    """Real weights → quantized serving tree (data-free, on the fly)."""
    return _walk(params, (), bits, method, group_size, False)


def dequant_kernel(params: dict, dtype) -> jnp.ndarray:
    """(out, in) float kernel from a quantized param dict."""
    if "w_q4" in params:
        from repro.quant.qtypes import unpack_int4
        codes = unpack_int4(params["w_q4"])
    else:
        codes = params["w_q"]
    return codes.astype(dtype) * params["w_scale"].astype(dtype)
