"""Quantized-weights serving format for the *distributed* model.

``QuantizedTensor`` (core pipeline output) is a single-host container; the
sharded serving path instead stores each kernel as two plain arrays living
in the params pytree —

    {"w": (in, out) bf16}  →  {"w_q":  (out, in)  int8      [w8]
                               "w_q4": (out, in/2) int8 packed [w4]
                               "w_scale": (out, 1) f32}

— so GSPMD shards them like any parameter (transposed kernel rules) and
``lax.scan`` over stacked layers still works. ``layers.linear`` and
``moe._expert_matmul`` consume this format directly (dequant-on-the-fly; the
Pallas dequant_matmul kernel is the TPU fast path).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.squant import squant_codes
from repro.quant.qtypes import pack_int4, qmax_for_bits
from repro.quant.scales import compute_scale


def _is_sds(x) -> bool:
    return isinstance(x, jax.ShapeDtypeStruct)


def _qdict_shapes(leaf, bits: int):
    """Shape stand-ins for one quantized kernel (stack dims preserved)."""
    *stack, d_in, d_out = leaf.shape
    key = "w_q4" if bits <= 4 else "w_q"
    qshape = tuple(stack) + ((d_out, d_in // 2) if bits <= 4
                             else (d_out, d_in))
    return {key: jax.ShapeDtypeStruct(qshape, jnp.int8),
            "w_scale": jax.ShapeDtypeStruct(tuple(stack) + (d_out, 1),
                                            jnp.float32)}


def _quantize_leaf(leaf: jnp.ndarray, bits: int, method: str,
                   group_size: Optional[int]):
    """Real quantization of one (possibly stacked) (in, out) kernel."""
    *stack, d_in, d_out = leaf.shape
    w2d = jnp.moveaxis(leaf.reshape(-1, d_in, d_out), -1, -2) \
        .reshape(-1, d_in)                       # (stack*out, in)
    scale = compute_scale(w2d, bits, "max")
    if method == "rtn":
        qmax = qmax_for_bits(bits)
        codes = jnp.clip(jnp.round(w2d / scale), -qmax, qmax)
    else:
        codes, _, _ = squant_codes(w2d, scale, bits=bits,
                                   group_size=group_size, enable_k=True,
                                   enable_c=True)
    codes = codes.astype(jnp.int8)
    if bits <= 4:
        data = pack_int4(codes).reshape(tuple(stack) + (d_out, d_in // 2))
        key = "w_q4"
    else:
        data = codes.reshape(tuple(stack) + (d_out, d_in))
        key = "w_q"
    return {key: data,
            "w_scale": scale.reshape(tuple(stack) + (d_out, 1))}


def _walk(node, path, bits, method, group_size, shapes_only):
    if isinstance(node, dict):
        out = {}
        for k, v in node.items():
            if (k == "w" and isinstance(v, dict) is False
                    and hasattr(v, "shape") and len(v.shape) >= 2
                    and "router" not in path
                    and "embedding" not in path):
                if shapes_only or _is_sds(v):
                    out.update(_qdict_shapes(v, bits))
                else:
                    out.update(_quantize_leaf(v, bits, method, group_size))
            else:
                out[k] = _walk(v, path + (k,), bits, method, group_size,
                               shapes_only)
        return out
    if isinstance(node, list):
        return [_walk(v, path + (str(i),), bits, method, group_size,
                      shapes_only) for i, v in enumerate(node)]
    return node


def quantized_param_shapes(params_shape: Any, bits: int) -> Any:
    """ShapeDtypeStruct tree for the quantized serving format."""
    return _walk(params_shape, (), bits, "squant", None, True)


def quantize_params_sharded(params: Any, bits: int, method: str = "squant",
                            group_size: Optional[int] = 128) -> Any:
    """Real weights → quantized serving tree (data-free, on the fly)."""
    return _walk(params, (), bits, method, group_size, False)


def is_quantized_tree(tree: Any) -> bool:
    """True if any node carries serving-format quantized leaves."""
    found = []

    def walk(node):
        if isinstance(node, dict):
            if "w_q" in node or "w_q4" in node:
                found.append(True)
            for v in node.values():
                walk(v)
        elif isinstance(node, list):
            for v in node:
                walk(v)

    walk(tree)
    return bool(found)


def quant_tree_meta(bits: int, method: str, group_size: Optional[int],
                    report=None, quantize_ms: Optional[float] = None) -> dict:
    """Checkpoint metadata for a quantized serving tree: the bits/method
    contract restore validates against, plus a ``QuantReport`` digest when
    the tree came through ``core.pipeline.quantize_tree``."""
    meta = {"bits": bits, "method": method, "group_size": group_size,
            "packed_int4": bits <= 4,
            "leaf_format": ("w_q4" if bits <= 4 else "w_q") + "+w_scale"}
    if quantize_ms is not None:
        meta["quantize_ms"] = quantize_ms
    if report is not None:
        meta["report"] = {"layers": len(report.layers),
                          "total_ms": report.total_millis,
                          "backend": report.backend,
                          "mesh_size": report.mesh_size}
    return meta


def quantize_params_serving(params: Any, bits: int, method: str = "squant",
                            group_size: Optional[int] = 128):
    """``(serving_tree, quant_meta)`` — the checkpointable quantized form.

    Same tree as :func:`quantize_params_sharded`, synchronized and timed so
    the metadata records the data-free quantization cost (Table-3 protocol).
    """
    import time
    t0 = time.perf_counter()
    tree = quantize_params_sharded(params, bits, method=method,
                                   group_size=group_size)
    jax.block_until_ready(jax.tree_util.tree_leaves(tree))
    ms = (time.perf_counter() - t0) * 1e3
    return tree, quant_tree_meta(bits, method, group_size, quantize_ms=ms)


def dequant_kernel(params: dict, dtype) -> jnp.ndarray:
    """(out, in) float kernel from a quantized param dict."""
    if "w_q4" in params:
        from repro.quant.qtypes import unpack_int4
        codes = unpack_int4(params["w_q4"])
    else:
        codes = params["w_q"]
    return codes.astype(dtype) * params["w_scale"].astype(dtype)
