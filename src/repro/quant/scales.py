"""Data-free quantization scale selection.

Per the paper (Sec. 4), SQuant uses per-channel symmetric weight scales; the
range can come from the channel max ("max") or an MSE-optimal clip search
("mse") — both are data-free (they look only at the weights).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.quant.qtypes import qmax_for_bits

_EPS = 1e-12


def _absmax(w2d: jnp.ndarray) -> jnp.ndarray:
    return jnp.max(jnp.abs(w2d), axis=-1, keepdims=True)


def max_scale(w2d: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Per-row symmetric max scale. w2d: (M, N) → (M, 1)."""
    return jnp.maximum(_absmax(w2d), _EPS) / qmax_for_bits(bits)


def mse_scale(w2d: jnp.ndarray, bits: int, num_candidates: int = 40,
              lo: float = 0.4) -> jnp.ndarray:
    """Per-row scale minimizing rounding MSE over a clip-ratio grid.

    Data-free: the search objective is the weight-space MSE of
    clip(round(w/s)) * s, evaluated per row over ``num_candidates`` clip
    ratios in [lo, 1.0].
    """
    qmax = qmax_for_bits(bits)
    base = jnp.maximum(_absmax(w2d), _EPS)        # (M, 1)
    ratios = jnp.linspace(lo, 1.0, num_candidates)  # (R,)
    scales = base[None] * ratios[:, None, None] / qmax  # (R, M, 1)
    q = jnp.clip(jnp.round(w2d[None] / scales), -qmax, qmax)
    err = jnp.sum((q * scales - w2d[None]) ** 2, axis=-1)  # (R, M)
    best = jnp.argmin(err, axis=0)                          # (M,)
    return jnp.take_along_axis(
        scales[:, :, 0].T, best[:, None], axis=1)           # (M, 1)


def compute_scale(w2d: jnp.ndarray, bits: int, method: str = "max",
                  group_size: Optional[int] = None) -> jnp.ndarray:
    """Scale for a (M, N) matrix.

    group_size=None → per-channel (M, 1)  [SQuant's setting]
    group_size=G    → per-group (M, N//G) [serving-format option; not used by
                      the SQuant CASE math, which requires a uniform scale per
                      channel — see DESIGN.md §2]
    """
    fn = {"max": max_scale, "mse": mse_scale}[method]
    if group_size is None:
        return fn(w2d, bits)
    m, n = w2d.shape
    if n % group_size != 0:
        raise ValueError(f"N={n} not divisible by group_size={group_size}")
    wg = w2d.reshape(m * (n // group_size), group_size)
    return fn(wg, bits).reshape(m, n // group_size)
