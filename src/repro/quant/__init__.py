"""Quantization substrate: formats, scales, packing, param-tree application."""
from repro.quant.qtypes import QuantizedTensor, pack_int4, unpack_int4  # noqa: F401
from repro.quant.scales import compute_scale  # noqa: F401
