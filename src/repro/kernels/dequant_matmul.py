"""Pallas TPU kernel: quantized-weight matmul (the SQuant serving hot spot).

Computes ``y = x @ dequant(Wq).T`` where ``Wq`` holds int8 codes (or int4
packed two-per-byte) with per-channel or per-group scales.

TPU mapping:
* grid (B/TB, M/TM, N/TN) with TN == group_size (128 default) so one K-tile
  sees exactly one scale per output row — the dequant is a tile-constant
  multiply fused after the MXU dot.
* codes are upcast to the activation dtype *inside VMEM* (the HBM traffic is
  the int8/int4 bytes — this is the memory-roofline win quantization buys).
* f32 accumulation in a VMEM scratch across the K grid dimension (TPU grids
  iterate the last axis innermost, so the revisiting-accumulator pattern is
  safe), scale applied per K-tile.
* int4: a (TM, TN/2) packed block is sign-extended with arithmetic shifts and
  re-interleaved — no gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _unpack_nibbles(packed: jnp.ndarray) -> jnp.ndarray:
    """(R, C) int8 → (R, 2C) int8, little-nibble-first (matches qtypes)."""
    lo = (packed << 4) >> 4          # arithmetic shifts sign-extend
    hi = packed >> 4
    return jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)


def _dequant_matmul_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *,
                           n_tiles: int, packed: bool):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...]
    if packed:
        w = _unpack_nibbles(w)
    x = x_ref[...]
    part = jnp.dot(x, w.astype(x.dtype).T,
                   preferred_element_type=jnp.float32)     # (TB, TM)
    acc_ref[...] += part * s_ref[...].reshape(1, -1)       # scale (TM,1)

    @pl.when(j == n_tiles - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "bits", "group_size", "tb", "tm", "interpret", "out_dtype"))
def dequant_matmul_pallas(x: jnp.ndarray, codes: jnp.ndarray,
                          scale: jnp.ndarray, *, bits: int,
                          group_size: int = 128, tb: int = 128, tm: int = 128,
                          interpret: bool = False, out_dtype=None):
    """y[B, M] = x[B, N] @ (codes[M, N] * scale).T

    ``codes``: int8; when bits<=4 they are packed (M, N/2) two-per-byte.
    ``scale``: (M, 1) per-channel or (M, N/group_size) per-group f32.
    """
    b, n = x.shape
    packed = bits <= 4
    m = codes.shape[0]
    n_codes = codes.shape[1] * (2 if packed else 1)
    if n_codes != n:
        raise ValueError(f"x has N={n} but codes unpack to {n_codes}")
    if n % group_size != 0:
        raise ValueError(f"N={n} not divisible by group_size={group_size}")
    ng = n // group_size
    scale_full = jnp.broadcast_to(scale.astype(jnp.float32).reshape(m, -1),
                                  (m, ng)) if scale.shape[1] != ng else scale
    out_dtype = out_dtype or x.dtype

    tb = min(tb, b)
    tm = min(tm, m)
    if b % tb or m % tm:
        raise ValueError(f"B={b} and M={m} must divide tiles ({tb},{tm})")
    tn = group_size
    n_tiles = ng
    wt = tn // 2 if packed else tn

    kern = functools.partial(_dequant_matmul_kernel, n_tiles=n_tiles,
                             packed=packed)
    return pl.pallas_call(
        kern,
        grid=(b // tb, m // tm, n_tiles),
        in_specs=[
            pl.BlockSpec((tb, tn), lambda i, k, j: (i, j)),
            pl.BlockSpec((tm, wt), lambda i, k, j: (k, j)),
            pl.BlockSpec((tm, 1), lambda i, k, j: (k, j)),
        ],
        out_specs=pl.BlockSpec((tb, tm), lambda i, k, j: (i, k)),
        out_shape=jax.ShapeDtypeStruct((b, m), out_dtype),
        scratch_shapes=[pltpu.VMEM((tb, tm), jnp.float32)],
        interpret=interpret,
    )(x, codes, scale_full)
