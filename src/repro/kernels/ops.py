"""Jitted public wrappers for the Pallas kernels.

Dispatch policy:
* On TPU backends the compiled Pallas kernels run natively.
* On CPU (this container) ``interpret=True`` executes the kernel body for
  correctness validation; the pure-jnp oracle is the default production
  fallback because interpret mode is slow for large tensors.

``use_pallas='auto'`` picks TPU→pallas, CPU→reference. Tests force
``use_pallas='interpret'`` to exercise the kernel bodies.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.dequant_matmul import dequant_matmul_pallas
from repro.kernels.squant_flip import squant_pallas
from repro.quant.qtypes import QuantizedTensor


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def squant_flip(w2d: jnp.ndarray, scale: jnp.ndarray, *, bits: int,
                group_size: int, enable_k: bool = True, enable_c: bool = True,
                use_pallas: str = "auto", tm: int = 8) -> jnp.ndarray:
    """SQuant codes for an (M, N) matrix with per-channel scales (M, 1).

    The Pallas path implements the standard E, E&K and E&K&C configurations;
    the E&C-without-K ablation (row-level flip) is reference-only.
    """
    if use_pallas == "auto":
        use_pallas = "pallas" if _on_tpu() else "ref"
    if use_pallas in ("pallas", "interpret") and (enable_k or not enable_c):
        return squant_pallas(w2d, scale, bits=bits, group_size=group_size,
                             enable_k=enable_k, enable_c=enable_c, tm=tm,
                             interpret=(use_pallas == "interpret"))
    return _ref.squant_ref(w2d, scale, bits=bits, group_size=group_size,
                           enable_k=enable_k, enable_c=enable_c)


def dequant_matmul(x: jnp.ndarray, qt: QuantizedTensor, *,
                   group_size: int = 128, use_pallas: str = "auto",
                   tb: int = 128, tm: int = 128) -> jnp.ndarray:
    """y = x @ dequant(qt).T for a (out, in)-major QuantizedTensor."""
    if use_pallas == "auto":
        use_pallas = "pallas" if _on_tpu() else "ref"
    import math
    m = qt.shape[0]
    n = math.prod(qt.shape[1:])
    scale = qt.scale.reshape(m, -1)
    if use_pallas in ("pallas", "interpret"):
        b = x.shape[0]
        # tile sizes must divide; shrink for small operands
        tb_eff = max(1, min(tb, b))
        while b % tb_eff:
            tb_eff -= 1
        tm_eff = max(1, min(tm, m))
        while m % tm_eff:
            tm_eff -= 1
        gs = group_size if n % group_size == 0 else n
        return dequant_matmul_pallas(
            x, qt.data, scale, bits=qt.bits, group_size=gs, tb=tb_eff,
            tm=tm_eff, interpret=(use_pallas == "interpret"))
    return _ref.dequant_matmul_ref(x, qt.data, scale, bits=qt.bits,
                                   group_size=group_size
                                   if n % group_size == 0 else n)
