"""Jitted public wrappers for the Pallas kernels.

Dispatch policy:
* On TPU backends the compiled Pallas kernels run natively.
* On CPU (this container) ``interpret=True`` executes the kernel body for
  correctness validation; the pure-jnp oracle is the default production
  fallback because interpret mode is slow for large tensors.

``use_pallas='auto'`` picks TPU→pallas, CPU→reference. Tests force
``use_pallas='interpret'`` to exercise the kernel bodies.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.dequant_matmul import dequant_matmul_pallas
from repro.kernels.squant_flip import squant_pallas
from repro.quant.qtypes import QuantizedTensor


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def squant_flip(w2d: jnp.ndarray, scale: jnp.ndarray, *, bits: int,
                group_size: int, enable_k: bool = True, enable_c: bool = True,
                use_pallas: str = "auto", tm: int = 8) -> jnp.ndarray:
    """SQuant codes for an (M, N) matrix with per-channel scales (M, 1).

    The Pallas path implements the standard E, E&K and E&K&C configurations;
    the E&C-without-K ablation (row-level flip) is reference-only.
    """
    if use_pallas == "auto":
        use_pallas = "pallas" if _on_tpu() else "ref"
    if use_pallas in ("pallas", "interpret") and (enable_k or not enable_c):
        return squant_pallas(w2d, scale, bits=bits, group_size=group_size,
                             enable_k=enable_k, enable_c=enable_c, tm=tm,
                             interpret=(use_pallas == "interpret"))
    return _ref.squant_ref(w2d, scale, bits=bits, group_size=group_size,
                           enable_k=enable_k, enable_c=enable_c)


def squant_flip_batched(w3: jnp.ndarray, scale3: jnp.ndarray, *, bits: int,
                        group_size: Optional[int], enable_k: bool = True,
                        enable_c: bool = True, use_pallas: str = "auto",
                        tm: int = 8) -> jnp.ndarray:
    """SQuant codes for a (B, M, N) stack of same-shape matrices.

    This is the model-level batched entry point: ``quantize_tree`` stacks all
    same-(shape, dtype) layers of a network into one bucket and issues ONE
    dispatch here instead of one per layer.

    SQuant is row-independent (every stage — E rounding, K group flips, C
    channel flips — operates within a single output channel), so the kernel
    backends flatten the batch into rows and launch the Pallas kernel once
    over ``(B*M, N)``; that is exact, not an approximation. The reference
    backend vmaps the jnp core instead. ``group_size=None`` (whole-row FC
    path) and the E&C-without-K ablation have no kernel specialization and
    always take the reference path.
    """
    if use_pallas == "auto":
        use_pallas = "pallas" if _on_tpu() else "ref"
    b, m, n = w3.shape
    if (use_pallas in ("pallas", "interpret") and group_size is not None
            and (enable_k or not enable_c)):
        codes = squant_pallas(w3.reshape(b * m, n),
                              scale3.reshape(b * m, 1), bits=bits,
                              group_size=group_size, enable_k=enable_k,
                              enable_c=enable_c, tm=tm,
                              interpret=(use_pallas == "interpret"))
        return codes.reshape(b, m, n)
    return _vmapped_ref(bits, group_size, enable_k, enable_c)(w3, scale3)


@functools.lru_cache(maxsize=None)
def _vmapped_ref(bits: int, group_size: Optional[int], enable_k: bool,
                 enable_c: bool):
    """jit(vmap(squant_ref)) cached per static config — without the outer jit
    the vmap traces through the jnp core op-by-op and per-dispatch overhead
    eats the batching win on small buckets."""
    fn = functools.partial(_ref.squant_ref, bits=bits, group_size=group_size,
                           enable_k=enable_k, enable_c=enable_c)
    return jax.jit(jax.vmap(fn))


def dequant_matmul(x: jnp.ndarray, qt: QuantizedTensor, *,
                   group_size: int = 128, use_pallas: str = "auto",
                   tb: int = 128, tm: int = 128) -> jnp.ndarray:
    """y = x @ dequant(qt).T for a (out, in)-major QuantizedTensor."""
    if use_pallas == "auto":
        use_pallas = "pallas" if _on_tpu() else "ref"
    import math
    m = qt.shape[0]
    n = math.prod(qt.shape[1:])
    scale = qt.scale.reshape(m, -1)
    if use_pallas in ("pallas", "interpret"):
        b = x.shape[0]
        # tile sizes must divide; shrink for small operands
        tb_eff = max(1, min(tb, b))
        while b % tb_eff:
            tb_eff -= 1
        tm_eff = max(1, min(tm, m))
        while m % tm_eff:
            tm_eff -= 1
        gs = group_size if n % group_size == 0 else n
        return dequant_matmul_pallas(
            x, qt.data, scale, bits=qt.bits, group_size=gs, tb=tb_eff,
            tm=tm_eff, interpret=(use_pallas == "interpret"))
    return _ref.dequant_matmul_ref(x, qt.data, scale, bits=qt.bits,
                                   group_size=group_size
                                   if n % group_size == 0 else n)
