"""Paged gather-attention decode kernel: one new query token per slot
attends over K/V read *through a block table* from a shared block pool.

Layout (what :class:`repro.serving.kvcache.PagedKVCache` feeds in):

* ``k_pool``/``v_pool``: ``(num_blocks, block_size, KV, D)`` — the pool.
* ``block_tables``: ``(B, nb)`` int32 — per-slot physical block ids, in
  logical order; unused tail entries point at the reserved trash block 0.
* ``lengths``: ``(B,)`` int32 — each slot's current absolute position
  (the new token's position; K/V for it are already written), so the
  kernel masks columns ``> lengths[b]``. No left-padding: slot ``b`` pays
  attention only over its own ``lengths[b] + 1`` real positions.

The Pallas kernel gathers one ``(block_size, D)`` K/V tile per grid step
into VMEM via scalar-prefetched block-table indexing (the BlockSpec
index_map reads ``block_tables`` directly, so the DMA fetches exactly the
blocks the slot owns) and accumulates a numerically-stable online softmax
per (slot, kv-head). The reference backend materializes the same gather
with jnp indexing and runs the exact grouped einsum the contiguous decode
path uses — it is the CPU serving oracle and the bit-identity anchor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30   # finite: exp(NEG_INF - m) underflows to exactly 0.0

__all__ = ["paged_attention", "paged_attention_ref"]


# ---------------------------------------------------------------------------
# reference backend (the serving oracle on CPU)
# ---------------------------------------------------------------------------

def paged_attention_ref(q, k_pool, v_pool, block_tables, lengths, *,
                        scale: float) -> jnp.ndarray:
    """q: (B, H, D); pools: (N, bs, KV, D); block_tables: (B, nb);
    lengths: (B,). Returns (B, H, D).

    Gathers each slot's blocks to a contiguous (B, nb*bs, KV, D) view and
    runs the same grouped einsum as the contiguous decode path
    (``models.attention._grouped_attention``), so greedy tokens stay
    bit-identical to the contiguous oracle when ``nb*bs == max_len``:
    masked columns hold finite garbage whose scores are pushed to
    ``NEG_INF`` and contribute exact zeros after softmax.
    """
    b, h, d = q.shape
    kv = k_pool.shape[2]
    bs = k_pool.shape[1]
    nb = block_tables.shape[1]
    t = nb * bs
    kc = k_pool[block_tables].reshape(b, t, kv, d).astype(q.dtype)
    vc = v_pool[block_tables].reshape(b, t, kv, d).astype(q.dtype)
    valid = jnp.arange(t)[None, :] <= lengths[:, None]
    mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
    mask = mask[:, None, None, None, :]                 # (B,1,1,S=1,T)
    rep = h // kv
    qg = q[:, None].reshape(b, 1, kv, rep, d)
    scores = jnp.einsum("bskrd,btkd->bkrst", qg, kc) * scale
    scores = scores.astype(jnp.float32)
    scores = scores + mask
    p = jax.nn.softmax(scores, axis=-1).astype(vc.dtype)
    out = jnp.einsum("bkrst,btkd->bskrd", p, vc)
    return out.reshape(b, 1, h, d)[:, 0]


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------

_LANES = 128   # replicate the (rep,) softmax stats across one vreg of lanes


def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, block_size: int,
                         scale: float):
    """Grid (B, KV, nb); one (block_size, D) K/V tile per step, online
    softmax accumulated across the nb (innermost, sequential) axis."""
    b = pl.program_id(0)
    j = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                # (rep, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bs, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)          # (bs, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    cols = j * block_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)                         # (rep, bs)
    s = jnp.where(cols <= len_ref[b], s, NEG_INF)

    m_prev = m_ref[...]                                # (rep, LANES)
    m_blk = jnp.max(s, axis=1, keepdims=True)          # (rep, 1)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_blk, m_prev.shape))
    alpha = jnp.exp(m_prev - m_new)                    # lane-replicated
    p = jnp.exp(s - m_new[:, :1])                      # (rep, bs)
    l_new = alpha * l_ref[...] + jnp.broadcast_to(
        jnp.sum(p, axis=1, keepdims=True), m_prev.shape)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, :1] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == nb - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / l_ref[:, :1]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def _paged_attention_pallas(q, k_pool, v_pool, block_tables, lengths, *,
                            scale: float, interpret: bool):
    b, h, d = q.shape
    n, bs, kv, _ = k_pool.shape
    nb = block_tables.shape[1]
    rep = h // kv
    qg = q.reshape(b, kv, rep, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, rep, d),
                         lambda bi, hi, ji, bt, ln: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda bi, hi, ji, bt, ln: (bt[bi, ji], 0, hi, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda bi, hi, ji, bt, ln: (bt[bi, ji], 0, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, d),
                               lambda bi, hi, ji, bt, ln: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, _LANES), jnp.float32),
            pltpu.VMEM((rep, _LANES), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_decode_kernel, block_size=bs,
                               scale=scale)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, rep, d), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, qg, k_pool, v_pool)
    return out.reshape(b, h, d)


# ---------------------------------------------------------------------------
# public dispatch (same policy as kernels.ops)
# ---------------------------------------------------------------------------

def paged_attention(q, k_pool, v_pool, block_tables, lengths, *,
                    scale: float, use_pallas: str = "auto") -> jnp.ndarray:
    """Block-table decode attention. ``use_pallas``: 'auto' (TPU→pallas,
    CPU→ref), 'ref', 'pallas', or 'interpret' (kernel body on CPU)."""
    if use_pallas == "auto":
        use_pallas = "pallas" if jax.default_backend() == "tpu" else "ref"
    if use_pallas in ("pallas", "interpret"):
        return _paged_attention_pallas(
            q, k_pool, v_pool, block_tables, lengths, scale=scale,
            interpret=(use_pallas == "interpret"))
    return paged_attention_ref(q, k_pool, v_pool, block_tables, lengths,
                               scale=scale)
