"""Fused int8 dequant paged-attention decode kernel.

The quantized sibling of :mod:`repro.kernels.paged_attention`: the block
pool stores int8 K/V codes plus per-(position, kv-head) fp32 scales
(``(num_blocks, block_size, KV)``), written by the same
``models.attention._quant_tok`` quantizer the contiguous backend uses.
Dequantization happens *inside VMEM* after the scalar-prefetched
block-table gather — the ``kernels/dequant_matmul.py`` idiom applied to
attention:

* K codes are upcast in VMEM and hit the MXU as-is; the per-column
  ``k_scale`` is folded into the scores *after* the QK dot (one
  (rep, bs) multiply instead of materializing a dequantized (bs, D)
  tile);
* ``v_scale`` is folded into the softmax weights *before* the PV dot
  (``(p * v_scale) @ v_codes``), so V codes also reach the MXU raw.

HBM traffic per decode token drops to ``2*D + 8`` bytes per (position,
kv-head) from ``2*D*itemsize`` for the fp pool — ~3.8x vs fp32, ~1.9x
vs bf16 — with no separate dequant materialization pass.

The jnp reference backend mirrors the kernel's op order (codes dot →
k-scale fold → mask → softmax → v-scale fold → codes dot) and is the CPU
serving oracle; interpret-mode parity is asserted in
``tests/test_kernels_paged_quant.py``. Greedy tokens from this path are
NOT bit-identical to the fp paged oracle — the tolerance-equivalence
harness (:mod:`repro.serving.equivalence`) budgets the divergence
instead (greedy-token agreement >= 0.98 per config).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.paged_attention import _LANES, NEG_INF

__all__ = ["paged_attention_quant", "paged_attention_quant_ref"]


# ---------------------------------------------------------------------------
# reference backend (the quantized serving oracle on CPU)
# ---------------------------------------------------------------------------

def paged_attention_quant_ref(q, k_pool, v_pool, k_scale, v_scale,
                              block_tables, lengths, *,
                              scale: float) -> jnp.ndarray:
    """q: (B, H, D); code pools: (N, bs, KV, D) int8; scale pools:
    (N, bs, KV) fp32; block_tables: (B, nb); lengths: (B,).
    Returns (B, H, D).

    Same gather as :func:`paged_attention_ref`, with the kernel's exact
    dequant order: scores = (q · codes) * softmax_scale * k_scale, then
    out = (softmax(scores) * v_scale) · v_codes. Never-written pool
    positions carry zero scales AND sit past ``lengths`` — the finite
    ``NEG_INF`` mask pushes them to exact-zero softmax weight.
    """
    b, h, d = q.shape
    kv = k_pool.shape[2]
    bs = k_pool.shape[1]
    nb = block_tables.shape[1]
    t = nb * bs
    kc = k_pool[block_tables].reshape(b, t, kv, d).astype(jnp.float32)
    vc = v_pool[block_tables].reshape(b, t, kv, d).astype(jnp.float32)
    ks = k_scale[block_tables].reshape(b, t, kv).transpose(0, 2, 1)
    vs = v_scale[block_tables].reshape(b, t, kv).transpose(0, 2, 1)
    valid = jnp.arange(t)[None, :] <= lengths[:, None]       # (B, T)
    rep = h // kv
    qg = q.astype(jnp.float32).reshape(b, kv, rep, d)
    scores = jnp.einsum("bkrd,btkd->bkrt", qg, kc) * scale
    scores = scores * ks[:, :, None, :]                      # fold k_scale
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    pw = p * vs[:, :, None, :]                               # fold v_scale
    out = jnp.einsum("bkrt,btkd->bkrd", pw, vc) / l
    return out.astype(q.dtype).reshape(b, h, d)


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------

def _paged_quant_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref,
                               ks_ref, vs_ref, o_ref, m_ref, l_ref,
                               acc_ref, *, block_size: int, scale: float):
    """Grid (B, KV, nb); one int8 (block_size, D) K/V code tile plus its
    (block_size,) scale vectors per step, online softmax across the nb
    (innermost, sequential) axis. Codes are upcast in VMEM; scales fold
    into the scores / softmax weights, never into materialized tiles."""
    b = pl.program_id(0)
    j = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                # (rep, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bs, D) int8 upcast
    v = v_ref[0, :, 0, :].astype(jnp.float32)          # (bs, D) int8 upcast
    ks = ks_ref[0, :, 0].reshape(1, block_size)        # (1, bs)
    vs = vs_ref[0, :, 0].reshape(1, block_size)        # (1, bs)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = s * ks                                         # per-column k_scale
    cols = j * block_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)                         # (rep, bs)
    s = jnp.where(cols <= len_ref[b], s, NEG_INF)

    m_prev = m_ref[...]                                # (rep, LANES)
    m_blk = jnp.max(s, axis=1, keepdims=True)          # (rep, 1)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_blk, m_prev.shape))
    alpha = jnp.exp(m_prev - m_new)                    # lane-replicated
    p = jnp.exp(s - m_new[:, :1])                      # (rep, bs)
    l_new = alpha * l_ref[...] + jnp.broadcast_to(
        jnp.sum(p, axis=1, keepdims=True), m_prev.shape)
    pv = jax.lax.dot_general(p * vs, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, :1] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == nb - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / l_ref[:, :1]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def _paged_attention_quant_pallas(q, k_pool, v_pool, k_scale, v_scale,
                                  block_tables, lengths, *, scale: float,
                                  interpret: bool):
    b, h, d = q.shape
    n, bs, kv, _ = k_pool.shape
    nb = block_tables.shape[1]
    rep = h // kv
    qg = q.reshape(b, kv, rep, d)

    def _tile(bi, hi, ji, bt, ln):
        return (bt[bi, ji], 0, hi, 0)

    def _stile(bi, hi, ji, bt, ln):
        return (bt[bi, ji], 0, hi)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, rep, d),
                         lambda bi, hi, ji, bt, ln: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bs, 1, d), _tile),     # K codes
            pl.BlockSpec((1, bs, 1, d), _tile),     # V codes
            pl.BlockSpec((1, bs, 1), _stile),       # k_scale
            pl.BlockSpec((1, bs, 1), _stile),       # v_scale
        ],
        out_specs=pl.BlockSpec((1, 1, rep, d),
                               lambda bi, hi, ji, bt, ln: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, _LANES), jnp.float32),
            pltpu.VMEM((rep, _LANES), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_quant_decode_kernel, block_size=bs,
                               scale=scale)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, rep, d), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, qg, k_pool, v_pool, k_scale, v_scale)
    return out.reshape(b, h, d)


# ---------------------------------------------------------------------------
# public dispatch (same policy as kernels.paged_attention)
# ---------------------------------------------------------------------------

def paged_attention_quant(q, k_pool, v_pool, k_scale, v_scale,
                          block_tables, lengths, *, scale: float,
                          use_pallas: str = "auto") -> jnp.ndarray:
    """Fused int8-dequant block-table decode attention. ``use_pallas``:
    'auto' (TPU→pallas, CPU→ref), 'ref', 'pallas', or 'interpret'."""
    if use_pallas == "auto":
        use_pallas = "pallas" if jax.default_backend() == "tpu" else "ref"
    if use_pallas in ("pallas", "interpret"):
        return _paged_attention_quant_pallas(
            q, k_pool, v_pool, k_scale, v_scale, block_tables, lengths,
            scale=scale, interpret=(use_pallas == "interpret"))
    return paged_attention_quant_ref(q, k_pool, v_pool, k_scale, v_scale,
                                     block_tables, lengths, scale=scale)
