"""Pallas TPU kernel for the SQuant progressive flip (Sec. 3.4).

TPU adaptation of the paper's CUDA kernel (one thread-block per output
channel + warp top-k). There is no warp shuffle / data-dependent sort on the
TPU vector unit, so selection is re-thought as *rank-via-comparison*:

    rank_i = Σ_j [score_j > score_i] + Σ_{j<i} [score_j == score_i]
    flip_i = rank_i < k

a dense (G×G) fixed-shape comparison that lives entirely in VMEM and maps
onto the 8×128 VPU lanes. Two passes:

* ``squant_ek_kernel`` — grid (M/TM, N/G), block (TM, G): fused
  round (SQuant-E) + group flip (SQuant-K) + the Algorithm-4 candidate
  (index+value) for the C stage.
* ``squant_c_kernel``  — grid (M/TM_C,), block (TM_C, NG): ranks groups by
  |candidate| and emits the per-group flip decision (SQuant-C). The ±1
  application is a cheap one-hot select done by the wrapper (no scatter —
  TPU-friendly).

Both are validated in interpret mode against ``kernels/ref.py`` (which
delegates to the vectorized core, itself bit-exact against the sequential
NumPy reference of Algorithms 1-4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ranks_desc_2d(score: jnp.ndarray) -> jnp.ndarray:
    """Stable descending rank along the last axis via pairwise comparison.

    score: (R, L) → int32 (R, L). Lower index wins ties (matches the stable
    argsort of the jnp reference).
    """
    r, l = score.shape
    s_i = score[:, :, None]                      # (R, L, 1) "self"
    s_j = score[:, None, :]                      # (R, 1, L) "other"
    ii = jax.lax.broadcasted_iota(jnp.int32, (r, l, l), 1)
    jj = jax.lax.broadcasted_iota(jnp.int32, (r, l, l), 2)
    beats = (s_j > s_i) | ((s_j == s_i) & (jj < ii))
    return jnp.sum(beats.astype(jnp.int32), axis=2)


def _flip_body(q, delta, qmax):
    """Shared E→K flip math on a (R, L) tile; returns updated (q, delta)."""
    e = jnp.sum(delta, axis=1, keepdims=True)
    k = jnp.round(jnp.abs(e)).astype(jnp.int32)
    tgt = q - jnp.sign(delta)
    in_range = (tgt >= -qmax) & (tgt <= qmax)
    eligible = (delta * e > 0) & in_range
    k = jnp.minimum(k, jnp.sum(eligible.astype(jnp.int32), axis=1,
                               keepdims=True))
    score = jnp.where(eligible, jnp.abs(delta), -1.0)
    flip = (_ranks_desc_2d(score) < k) & eligible
    sgn = jnp.sign(delta)
    q = q - jnp.where(flip, sgn, 0.0)
    delta = delta - jnp.where(flip, sgn, 0.0)
    return q, delta


def squant_ek_kernel(w_ref, inv_s_ref, q_ref, d_ref, e1_ref, cidx_ref,
                     cval_ref, *, qmax: float, enable_k: bool):
    """Fused SQuant-E (+K) + Algorithm-4 candidate for one (TM, G) block."""
    w = w_ref[...].astype(jnp.float32) * inv_s_ref[...]
    q = jnp.clip(jnp.round(w), -qmax, qmax)
    delta = q - w

    if enable_k:
        q, delta = _flip_body(q, delta, qmax)

    # Post-K group sum and the single C-stage candidate (Algorithm 4).
    e1 = jnp.sum(delta, axis=1, keepdims=True)          # (TM, 1)
    sgn1 = jnp.sign(e1)
    match = jnp.where(sgn1 == 0.0, delta != 0.0, delta * sgn1 > 0.0)
    tgt = q - jnp.sign(delta)
    match = match & (tgt >= -qmax) & (tgt <= qmax)
    cscore = jnp.where(match, jnp.abs(delta), -1.0)
    cmax = jnp.max(cscore, axis=1, keepdims=True)       # (TM, 1)
    l = cscore.shape[1]
    ii = jax.lax.broadcasted_iota(jnp.int32, cscore.shape, 1)
    first = jnp.min(jnp.where(cscore == cmax, ii, l), axis=1, keepdims=True)
    cand_val = jnp.sum(jnp.where(ii == first, delta, 0.0), axis=1,
                       keepdims=True)
    has = cmax > 0.0
    q_ref[...] = q.astype(jnp.int32)
    d_ref[...] = delta
    e1_ref[...] = e1
    cidx_ref[...] = jnp.where(has, first, -1).astype(jnp.int32)
    cval_ref[...] = jnp.where(has, cand_val, 0.0)


def squant_c_kernel(e1_ref, cval_ref, gflip_ref):
    """SQuant-C decision on one (TM_C, NG) block of group summaries."""
    e1 = e1_ref[...]
    cval = cval_ref[...]
    e_row = jnp.sum(e1, axis=1, keepdims=True)
    k_c = jnp.round(jnp.abs(e_row)).astype(jnp.int32)
    elig = (cval * e_row > 0.0)                          # cval==0 → ineligible
    k_c = jnp.minimum(k_c, jnp.sum(elig.astype(jnp.int32), axis=1,
                                   keepdims=True))
    score = jnp.where(elig, jnp.abs(cval), -1.0)
    gflip = (_ranks_desc_2d(score) < k_c) & elig
    gflip_ref[...] = gflip.astype(jnp.int32)


def _pad_to(x, mult, axis, value=0.0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=(
    "bits", "group_size", "enable_k", "enable_c", "tm", "interpret"))
def squant_pallas(w2d: jnp.ndarray, scale: jnp.ndarray, *, bits: int,
                  group_size: int, enable_k: bool = True,
                  enable_c: bool = True, tm: int = 8,
                  interpret: bool = False):
    """Full SQuant E(&K)(&C) via the two Pallas passes. Returns int8 codes.

    w2d: (M, N) float; scale: (M, 1). N is padded to a multiple of
    ``group_size``, M to a multiple of ``tm`` (zero rows/cols are inert:
    δ=0 elements are never flip-eligible and contribute nothing to sums).
    """
    qmax = float(2 ** (bits - 1) - 1)
    m0, n0 = w2d.shape
    g = group_size
    w = _pad_to(_pad_to(w2d.astype(jnp.float32), g, 1), tm, 0)
    inv_s = _pad_to(1.0 / scale.astype(jnp.float32).reshape(m0, 1), tm, 0,
                    value=1.0)
    m, n = w.shape
    ng = n // g

    grid = (m // tm, ng)
    kern = functools.partial(squant_ek_kernel, qmax=qmax, enable_k=enable_k)
    q, delta, e1, cidx, cval = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, g), lambda i, j: (i, j)),
            pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tm, g), lambda i, j: (i, j)),
            pl.BlockSpec((tm, g), lambda i, j: (i, j)),
            pl.BlockSpec((tm, 1), lambda i, j: (i, j)),
            pl.BlockSpec((tm, 1), lambda i, j: (i, j)),
            pl.BlockSpec((tm, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, ng), jnp.float32),
            jax.ShapeDtypeStruct((m, ng), jnp.int32),
            jax.ShapeDtypeStruct((m, ng), jnp.float32),
        ],
        interpret=interpret,
    )(w, inv_s)

    if enable_c:
        # keep the (TM_C, NG, NG) comparison tensor under ~2 MiB of VMEM;
        # tm_c must divide the (tm-padded) m or the floor-divided grid
        # leaves the last m % tm_c rows of gflip unwritten
        tm_c = max(1, min(tm, (1 << 19) // max(ng * ng, 1)))
        while m % tm_c:
            tm_c -= 1
        gflip = pl.pallas_call(
            squant_c_kernel,
            grid=(m // tm_c,),
            in_specs=[
                pl.BlockSpec((tm_c, ng), lambda i: (i, 0)),
                pl.BlockSpec((tm_c, ng), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((tm_c, ng), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((m, ng), jnp.int32),
            interpret=interpret,
        )(e1, cval)
        # apply: one ±1 mutation per flipped group at the candidate position
        qg = q.reshape(m, ng, g)
        ii = jax.lax.broadcasted_iota(jnp.int32, qg.shape, 2)
        hit = (ii == cidx[..., None]) & (gflip[..., None] > 0)
        qg = qg - jnp.where(hit, jnp.sign(cval)[..., None], 0.0).astype(q.dtype)
        q = qg.reshape(m, n)

    return q[:m0, :n0].astype(jnp.int8)
