"""Pure-jnp oracles for the Pallas kernels.

``squant_ref`` delegates to the vectorized core (itself bit-exact against the
sequential NumPy transcription of Algorithms 1-4), so the chain of evidence is
  Pallas(interpret) == vectorized jnp == sequential NumPy pseudocode.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.squant import squant_codes
from repro.quant.qtypes import unpack_int4


def squant_ref(w2d: jnp.ndarray, scale: jnp.ndarray, *, bits: int,
               group_size: Optional[int], enable_k: bool = True,
               enable_c: bool = True) -> jnp.ndarray:
    codes, _, _ = squant_codes(w2d, scale, bits=bits, group_size=group_size,
                               enable_k=enable_k, enable_c=enable_c)
    return codes


def dequant_matmul_ref(x: jnp.ndarray, codes: jnp.ndarray,
                       scale: jnp.ndarray, *, bits: int,
                       group_size: int = 128) -> jnp.ndarray:
    """y = x @ dequant(codes).T with per-channel or per-group scales."""
    m = codes.shape[0]
    c = unpack_int4(codes) if bits <= 4 else codes
    c = c.astype(jnp.float32)
    n = c.shape[1]
    ng = n // group_size
    s = jnp.broadcast_to(scale.astype(jnp.float32).reshape(m, -1), (m, ng))
    w = (c.reshape(m, ng, group_size) * s[..., None]).reshape(m, n)
    return (x.astype(jnp.float32) @ w.T).astype(x.dtype)
