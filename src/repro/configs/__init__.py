"""Architecture configs: one module per assigned architecture."""
from repro.configs.base import ArchConfig, MoEConfig, MambaConfig  # noqa: F401
from repro.configs.registry import get_config, list_archs  # noqa: F401
from repro.configs.shapes import SHAPES, ShapeSpec, cells  # noqa: F401
