"""Mixtral-8x7B: 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14_336, vocab=32_000,
    window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, every=1),
    ffn_kind="swiglu", rope_theta=10_000.0,
    sub_quadratic=True,   # SWA ⇒ O(window) decode state
)
