"""Moonlight-16B-A3B (moonshot): fine-grained MoE, 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163_840,
    moe=MoEConfig(n_experts=64, top_k=6, every=1),
    ffn_kind="swiglu", rope_theta=10_000.0,
)
