"""Chameleon-34B: early-fusion VLM over a unified VQ-token vocabulary; the
image tokenizer is the stubbed frontend (inputs arrive as discrete codes in
the shared vocab); qk-norm per the paper [arXiv:2405.09818]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22_016, vocab=65_536,
    qk_norm=True, ffn_kind="swiglu", rope_theta=10_000.0,
    tie_embeddings=False,
)
