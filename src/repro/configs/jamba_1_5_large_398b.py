"""Jamba-1.5-Large (398B): Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer [arXiv:2403.19887]."""
from repro.configs.base import ArchConfig, MambaConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24_576, vocab=65_536,
    # one attention layer per 8 (position 4 of each period, Jamba paper)
    block_pattern=("m", "m", "m", "m", "a", "m", "m", "m"),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(n_experts=16, top_k=2, every=2),
    ffn_kind="swiglu", rope_theta=10_000.0,
    sub_quadratic=True,
    tie_embeddings=False,
)
