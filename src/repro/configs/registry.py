"""Architecture registry: --arch <id> → ArchConfig."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ArchConfig

_MODULES = (
    "minitron_4b", "minicpm3_4b", "gemma_7b", "granite_3_8b",
    "jamba_1_5_large_398b", "seamless_m4t_medium", "chameleon_34b",
    "moonshot_v1_16b_a3b", "mixtral_8x7b", "rwkv6_1_6b",
)


def _load() -> Dict[str, ArchConfig]:
    import importlib
    out = {}
    for m in _MODULES:
        cfg = importlib.import_module(f"repro.configs.{m}").CONFIG
        out[cfg.name] = cfg
    return out


_REGISTRY: Dict[str, ArchConfig] = {}


def get_config(name: str, reduced: bool = False) -> ArchConfig:
    global _REGISTRY
    if not _REGISTRY:
        _REGISTRY = _load()
    cfg = _REGISTRY[name]
    return cfg.reduced() if reduced else cfg


def list_archs() -> List[str]:
    global _REGISTRY
    if not _REGISTRY:
        _REGISTRY = _load()
    return sorted(_REGISTRY)
