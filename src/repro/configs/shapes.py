"""Assigned input shapes (one set shared by all 10 LM-family archs).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of seq_len), NOT ``train_step``. ``long_500k`` requires sub-quadratic
sequence mixing — it runs only for archs with ``sub_quadratic=True``
(jamba / rwkv6 / mixtral-SWA); pure full-attention archs skip it (recorded
as N/A in EXPERIMENTS.md, rationale in DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k":    ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cells(cfgs) -> List[Tuple[str, str, str]]:
    """All (arch, shape, status) cells; status 'run' or 'skip:<reason>'."""
    out = []
    for cfg in cfgs:
        for name, sh in SHAPES.items():
            status = "run"
            if name == "long_500k" and not cfg.sub_quadratic:
                status = "skip:full-attention (O(S) dense KV at 512k)"
            out.append((cfg.name, name, status))
    return out
