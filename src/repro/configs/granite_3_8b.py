"""Granite-3.0-8B: llama-style GQA [hf:ibm-granite/granite-3.0-8b-base]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12_800, vocab=49_155,
    ffn_kind="swiglu", rope_theta=10_000.0,
)
