"""Gemma-7B: GeGLU, head_dim 256, (1+g) RMSNorm, scaled embeddings
[arXiv:2403.08295]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
    d_ff=24_576, vocab=256_000,
    head_dim=256, ffn_kind="geglu",
    emb_scale=True, norm_plus_one=True,
    rope_theta=10_000.0,
)
