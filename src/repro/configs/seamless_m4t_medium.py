"""SeamlessM4T-medium backbone: 12L enc + 12L dec, frontend stubbed (encoder
consumes precomputed audio-frame embeddings) [arXiv:2308.11596]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256_206,
    encoder_layers=12, frontend_stub=True, enc_ratio=4,
    ffn_kind="gelu", rope_theta=10_000.0,
    tie_embeddings=False,
)
