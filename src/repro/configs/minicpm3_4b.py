"""MiniCPM3-4B: deep-thin dense model with MLA [hf:openbmb/MiniCPM3-4B]."""
from repro.configs.base import ArchConfig
from repro.models.attention import MLAConfig

CONFIG = ArchConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab=73_448,
    head_dim=64,
    mla=MLAConfig(q_lora=768, kv_lora=256, nope_dim=64, rope_dim=32,
                  v_dim=64),
    ffn_kind="swiglu", rope_theta=10_000.0,
)
