"""RWKV-6 "Finch" 1.6B: attention-free, data-dependent decay
[arXiv:2404.05892]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65_536,
    rwkv=True, rwkv_head_dim=64,
    sub_quadratic=True,
    tie_embeddings=False,
)
