"""Architecture configuration dataclasses.

Every assigned architecture is an ``ArchConfig`` instance (exact published
dimensions); ``reduced()`` derives the small same-family variant used by CPU
smoke tests (full configs are exercised only via the dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.models.attention import MLAConfig


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    every: int = 1             # MoE replaces the FFN every N layers
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None      # default d_model // n_heads
    ffn_kind: str = "swiglu"
    # attention flavor
    window: Optional[int] = None        # sliding-window attention
    qk_norm: bool = False
    mla: Optional[MLAConfig] = None
    rope_theta: float = 10000.0
    # MoE / hybrid / rwkv
    moe: Optional[MoEConfig] = None
    block_pattern: Optional[Tuple[str, ...]] = None   # per-period, "a"/"m"
    mamba: Optional[MambaConfig] = None
    rwkv: bool = False
    rwkv_head_dim: int = 64
    # encoder-decoder (audio frontend stubbed: encoder consumes embeddings)
    encoder_layers: int = 0
    frontend_stub: bool = False
    enc_ratio: int = 4                  # dec tokens per enc frame (shapes)
    # misc
    emb_scale: bool = False             # gemma: embeddings × sqrt(d)
    norm_plus_one: bool = False         # gemma: (1+g) RMSNorm
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    sub_quadratic: bool = False         # eligible for long_500k
    # memory knobs (defaults; per-cell overrides in launch/dryrun.py)
    remat: bool = True
    scan_layers: bool = True
    # chunk sizes bounding working sets (seq must divide cleanly)
    attn_q_chunk: int = 1024
    mamba_chunk: int = 512
    rwkv_chunk: int = 32
    # costing mode: python-loop the chunk/microbatch scans so XLA
    # cost_analysis sees every iteration (it does not multiply loop trips)
    unroll_chunks: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        small_moe = None
        if self.moe is not None:
            small_moe = dataclasses.replace(
                self.moe, n_experts=min(4, self.moe.n_experts),
                top_k=min(2, self.moe.top_k))
        small_mla = None
        if self.mla is not None:
            small_mla = MLAConfig(q_lora=16, kv_lora=8, nope_dim=8,
                                  rope_dim=4, v_dim=8)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(4, self.n_layers) if self.block_pattern is None
            else len(self.block_pattern),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4
                                  // max(self.n_heads, 1))),
            head_dim=16 if self.mla is None else None,
            d_ff=128,
            vocab=256,
            window=min(self.window, 32) if self.window else None,
            moe=small_moe,
            mla=small_mla,
            rwkv_head_dim=16,
            encoder_layers=2 if self.encoder_layers else 0,
            mamba=MambaConfig(d_state=8) if self.mamba else None,
            scan_layers=self.scan_layers,
        )
