"""Batched serving engine with quantized-weight and quantized-KV paths,
backed by a versioned hot-reloadable weight store."""
from repro.serving.engine import ServeEngine, ServeConfig  # noqa: F401
from repro.serving.weights import (WeightStore,  # noqa: F401
                                   WeightVersion, make_weight_pipeline)
