"""Batched serving engine (round or continuous-batching slot scheduler)
with quantized-weight and quantized-KV paths, a first-class KV-cache API
(contiguous or paged-with-prefix-reuse), backed by a versioned
hot-reloadable weight store."""
from repro.serving.engine import (ServeEngine, ServeConfig,  # noqa: F401
                                  Request, Completion)
from repro.serving.kvcache import (KVCache,  # noqa: F401
                                   ContiguousKVCache, PagedKVCache)
from repro.serving.scheduler import (RoundScheduler,  # noqa: F401
                                     ContinuousScheduler)
from repro.serving.weights import (WeightStore,  # noqa: F401
                                   WeightVersion, make_weight_pipeline)
