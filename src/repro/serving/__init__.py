"""Batched serving engine (round or continuous-batching slot scheduler)
with quantized-weight and quantized-KV paths, a first-class KV-cache API
(contiguous or paged-with-prefix-reuse), self-speculative decoding (the
low-bit quantization drafts for the serving tree), backed by a versioned
hot-reloadable weight store.

The deliberate public surface lives in :mod:`repro.serving.api`
(``Request``/``Completion``/``StagedInfo``/``SchedulerStats``) and is
re-exported here; ``repro.serving.engine.Request`` and
``repro.serving.scheduler.Request`` remain as deprecated aliases."""
from repro.serving.api import (Request, Completion,  # noqa: F401
                               StagedInfo, SchedulerStats)
from repro.serving.engine import ServeEngine, ServeConfig  # noqa: F401
from repro.serving.kvcache import (KVCache,  # noqa: F401
                                   ContiguousKVCache, PagedKVCache)
from repro.serving.scheduler import (RoundScheduler,  # noqa: F401
                                     ContinuousScheduler)
from repro.serving.speculative import SpeculativeDecoder  # noqa: F401
from repro.serving.weights import (WeightStore,  # noqa: F401
                                   WeightVersion, make_weight_pipeline)
