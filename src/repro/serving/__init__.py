"""Batched serving engine with quantized-weight and quantized-KV paths."""
from repro.serving.engine import ServeEngine, ServeConfig  # noqa: F401
