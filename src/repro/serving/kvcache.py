"""First-class KV-cache API for the serving engine.

Everything the schedulers used to do to cache dicts by hand — allocation
(``model.init_cache``), the decode-position clock (``cache["pos"]``
poking), admission side caches, row scatter (``admit_rows``) — is owned
here, behind one interface with two backends:

* :class:`ContiguousKVCache` — the original layout: ONE ``max_slots``-row
  cache, slot = cache row, a single scalar clock shared by every slot,
  prompts left-padded to the clock at admission. It wraps the exact same
  jitted calls the scheduler used to make, so it is the bit-exactness
  oracle (and the trace-count behavior is unchanged).

* :class:`PagedKVCache` — vLLM-style paging: K/V live in a pool of
  fixed-size blocks; each slot reaches its tokens through a per-slot
  block table, with a free-block pool, refcounts, and copy-on-write.
  Prompts are *not* left-padded: slot ``b``'s tokens sit at absolute
  positions ``0..L-1`` with a per-slot length vector as the clock, which
  removes the contiguous backend's ``clock + max_new <= max_len``
  admission horizon (a long-budget request no longer has to fit under the
  shared clock — only under its own ``prompt + max_new <= max_len``).

Shared-prefix reuse (paged): admitted prompts are chain-hashed at block
granularity; full blocks whose hash (and content — hashes are verified
against stored tokens) matches a registered block are *shared* into the
new slot's table with a refcount bump, and the admission prefill shrinks
to the unshared suffix (a ``prefill_chunk`` continuation over the
gathered prefix, bit-identical to the monolithic prefill by the chunked-
prefill equivalence). A partially-filled tail block can also be shared
for its values; any write into a block with live sharers triggers
copy-on-write — the slot gets a private physical block before the write
lands (``cow_copies`` counts these). Retired slots' blocks drop their
refs; registered blocks park in a reclaimable cached set (evicted FIFO
when the free list runs dry) instead of being freed, so one 512-token
system prompt prefills once across thousands of short turns.

Physical block 0 is reserved as the *trash block*: retired slots' table
rows point at it, so the lockstep decode batch (which writes one K/V row
per slot unconditionally) can never corrupt a live block.

Admission reserves the full ``ceil((len(prompt) + max_new) / block_size)``
block budget up front (allocated lazily as decode crosses block
boundaries), so a request that is admitted can always finish: pool
exhaustion surfaces as admission backpressure, never as a mid-decode
failure.

Chunked admission (``prefill_chunk > 0``) splits the paged admission into
a multi-step lifecycle driven by the scheduler's ``PagedPendingPrefill``:
``reserve_pending`` (full block budget outstanding before the first
chunk), ``begin_chunked_admit`` (prefix pin + gather — pins happen before
any chunk so mid-admission FIFO eviction can't recycle a block about to
be read), N ``prefill_chunk`` continuations on the 1-row side cache while
residents decode, then ``complete_chunked_admit`` (the same alloc/COW/
scatter/register commit as monolithic admission, at the slot's own prompt
length) — or ``abandon_chunked_admit`` on a force-swap, which unpins and
releases the reservation.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

TRASH = 0   # reserved physical block: write target for retired slots

__all__ = ["KVCache", "ContiguousKVCache", "PagedKVCache", "admit_rows"]


# ---------------------------------------------------------------------------
# jitted cache ops (pure functions; the engine wraps them in trace counters)
# ---------------------------------------------------------------------------

def admit_rows(pool, tmp, pool_logits, tmp_logits, idx):
    """Scatter a ``k``-row prefill cache + its last-token logits into the
    ``max_slots``-row pool at slot indices ``idx``.

    Cache leaves are batch-leading except scan-stacked period caches
    (``(periods, batch, ...)`` — batch at axis 1) and the scalar ``pos``,
    which the admission prefill computed for the new clock and which simply
    replaces the pool's (both equal the clock while slots are in flight; on
    a fresh wave it rewinds the pool).
    """
    out = dict(pool)

    def rows0(a, b):
        return a.at[idx].set(b.astype(a.dtype))

    def rows1(a, b):
        return a.at[:, idx].set(b.astype(a.dtype))

    for key in pool:
        if key == "pos":
            continue
        out[key] = jax.tree_util.tree_map(
            rows1 if key == "periods" else rows0, pool[key], tmp[key])
    out["pos"] = tmp["pos"]
    return out, pool_logits.at[idx].set(tmp_logits.astype(pool_logits.dtype))


def gather_blocks(side, pool, phys):
    """Copy pool blocks ``phys`` (logical order, ``(n,)`` int32) into the
    first ``n * block_size`` positions of the 1-row contiguous ``side``
    cache — the admission-side materialization of a shared prefix."""
    out = dict(side)

    def g0(s, p):                                  # batch-leading leaves
        blk = p[phys]                              # (n, bs, ...)
        flat = blk.reshape((1, -1) + blk.shape[2:]).astype(s.dtype)
        return jax.lax.dynamic_update_slice_in_dim(s, flat, 0, 1)

    def g1(s, p):                                  # (periods, batch, ...)
        blk = p[:, phys]
        flat = blk.reshape((blk.shape[0], 1, -1)
                           + blk.shape[3:]).astype(s.dtype)
        return jax.lax.dynamic_update_slice_in_dim(s, flat, 0, 2)

    for key in ("periods", "list"):
        if key in pool:
            out[key] = jax.tree_util.tree_map(
                g1 if key == "periods" else g0, side[key], pool[key])
    return out


def scatter_blocks(pool, side, phys, start):
    """Write side-cache positions ``[start*bs, (start+n)*bs)`` into pool
    blocks ``phys`` (``(n,)`` int32; ``start`` may be traced)."""
    out = dict(pool)
    n = phys.shape[0]

    def s0(p, s):
        bs = p.shape[1]
        seg = jax.lax.dynamic_slice_in_dim(s, start * bs, n * bs, 1)
        return p.at[phys].set(
            seg.reshape((n, bs) + seg.shape[2:]).astype(p.dtype))

    def s1(p, s):
        bs = p.shape[2]
        seg = jax.lax.dynamic_slice_in_dim(s, start * bs, n * bs, 2)
        return p.at[:, phys].set(
            seg.reshape((seg.shape[0], n, bs) + seg.shape[3:])
            .astype(p.dtype))

    for key in ("periods", "list"):
        if key in pool:
            out[key] = jax.tree_util.tree_map(
                s1 if key == "periods" else s0, pool[key], side[key])
    return out


def copy_block(pool, src, dst):
    """Pool-to-pool block copy (decode-time copy-on-write)."""
    out = dict(pool)

    def c0(p):
        return p.at[dst].set(p[src])

    def c1(p):
        return p.at[:, dst].set(p[:, src])

    for key in ("periods", "list"):
        if key in pool:
            out[key] = jax.tree_util.tree_map(
                c1 if key == "periods" else c0, pool[key])
    return out


# ---------------------------------------------------------------------------
# interface
# ---------------------------------------------------------------------------

class KVCache:
    """Backend-neutral KV-cache state owned on behalf of a scheduler.

    Use :meth:`create` (reads ``ServeConfig.kv_backend``); schedulers talk
    to the returned object and never touch cache dicts or ``cache["pos"]``
    directly — the decode position is the read-only :attr:`clock`.
    """

    backend = "abstract"

    def __init__(self, engine):
        self.eng = engine
        self.cfg = engine.cfg
        self.model = engine.model
        self.max_slots = engine.cfg.max_slots or engine.cfg.max_batch
        self._cache = None            # persistent pool cache (lazy init)
        self._logits = None           # (max_slots, vocab) pending logits
        # admission side caches, keyed by row count and reused across
        # admissions: a fresh allocation per admission owned the admission
        # step's latency at small scales. Stale rows are harmless — every
        # position is rewritten before any masked-in read, and masked
        # columns contribute exact zeros — only the clock is rewound.
        self._side_caches: Dict[int, Any] = {}

    @staticmethod
    def create(engine) -> "KVCache":
        """The one serving entry point for cache construction (unifies the
        old ``models.model.LM.init_cache`` / ``models.transformer.
        init_cache`` call sites and the ``quantize_kv`` flag)."""
        backend = getattr(engine.cfg, "kv_backend", "contiguous")
        if backend == "paged":
            return PagedKVCache(engine)
        return ContiguousKVCache(engine)

    # ------------------------------------------------------------- plumbing
    def fresh(self, rows: int) -> dict:
        """A standalone contiguous cache (the round scheduler's per-round
        cache); replaces direct ``model.init_cache`` calls in serving."""
        return self.model.init_cache(rows, self.cfg.max_len,
                                     quantize_kv=self.cfg.quantize_kv)

    def side_cache(self, k: int) -> dict:
        """A reusable ``k``-row admission cache with the clock rewound.

        Stacks with recurrent state (mamba/rwkv) get a fresh allocation
        every time: their state leaves are read at the first chunk of a
        chunked admission — a retired request's state is not masked out
        the way stale KV rows are, so reuse would leak it into the new
        request's recurrence."""
        if self.model.has_recurrent_state():
            return self.fresh(k)
        cache = self._side_caches.get(k)
        if cache is None:
            cache = self.fresh(k)
            self._side_caches[k] = cache
        cache = dict(cache)
        cache["pos"] = jnp.zeros((), jnp.int32)
        return cache

    @property
    def logits(self):
        """Last-token logits per slot, sampled by the scheduler."""
        return self._logits

    def begin_run(self) -> None:
        """Called at the top of each ``run()``."""

    def check_request(self, req) -> None:
        """Backend-specific admissibility (beyond the shared horizon)."""

    def on_weight_swap(self) -> None:
        """Invalidate weight-version-dependent cached state."""

    def rewind(self, slot_id: int, n: int) -> None:
        """Roll slot ``slot_id`` back by ``n`` positions (speculative
        decoding rejects drafted tokens). Contiguous caches share one
        clock across slots and cannot rewind one slot — the config gate
        keeps speculation off this backend."""
        raise NotImplementedError(
            f"rewind is not supported by the {self.backend!r} KV backend")

    def stats(self) -> Dict[str, Any]:
        return {"backend": self.backend}


# ---------------------------------------------------------------------------
# contiguous backend (the original layout — bit-exactness oracle)
# ---------------------------------------------------------------------------

class ContiguousKVCache(KVCache):
    """One ``max_slots``-row cache with a shared scalar clock; admission
    left-pads prompts to the clock and scatters side-cache rows into the
    pool (the ``admit_rows`` path). Wraps exactly the device calls the
    continuous scheduler used to issue, so slots' greedy tokens — and the
    engine's jit trace counts — are unchanged by the API move."""

    backend = "contiguous"

    def __init__(self, engine):
        super().__init__(engine)
        self._clock = 0

    @property
    def clock(self) -> int:
        """The shared decode position. Read-only: the clock advances via
        :meth:`decode` and is set by admissions — direct ``cache["pos"]``
        mutation is deprecated in favor of this property."""
        return self._clock

    def begin_run(self) -> None:
        self._clock = 0

    # ----------------------------------------------------------- admission
    def pick(self, queue, nfree: int, fresh: bool, limit_head: bool
             ) -> Tuple[List, Optional[int]]:
        """Choose up to ``nfree`` queued requests admissible at the clock.

        Mid-flight (``fresh=False``): FCFS with skip — a request fits iff
        its prompt fits under the clock (``L <= clock``; the clock advances
        one position per step, so longer prompts become admissible soon)
        and its budget fits the cache horizon. ``limit_head`` narrows the
        scan to the queue head (the starvation guard's anti-skip mode).

        Fresh wave (``fresh=True``): the pool is empty, so the clock
        restarts at the wave's longest admitted prompt. The queue head is
        always admitted (its own ``L + max_new <= max_len`` was validated
        at submit), guaranteeing progress; growing the wave re-checks every
        already-chosen request against the raised clock so admission never
        invalidates an earlier choice.
        """
        max_len = self.cfg.max_len
        clock = self._clock
        chosen: List = []
        new_clock = 0 if fresh else clock
        items = [queue[0]] if (limit_head and not fresh) else list(queue)
        for item in items:
            if len(chosen) >= nfree:
                break
            _, r = item
            if fresh:
                cand = max(new_clock, len(r.prompt))
                if (cand + r.max_new_tokens <= max_len
                        and all(cand + c.max_new_tokens <= max_len
                                for _, c in chosen)):
                    chosen.append(item)
                    new_clock = cand
            else:
                if (len(r.prompt) <= clock
                        and clock + r.max_new_tokens <= max_len):
                    chosen.append(item)
        for item in chosen:
            queue.remove(item)
        return chosen, new_clock

    def solve_target(self, longest: int) -> Optional[int]:
        """Committed completion clock for a mid-flight chunked admission.

        The pending consumes ``chunk`` positions per engine step while
        residents advance the clock one per step, so completing at clock
        ``P = clock + s - 1`` after ``s`` chunk-steps requires the chunks
        to cover all ``P`` positions (``s * chunk >= P``) and the prompt to
        fit the padding (``P >= longest``; prompts *longer than the clock*
        are admissible — the chunks catch up, which the monolithic path
        cannot do at all). Returns None when no ``s`` exists (``chunk == 1``
        against a moving clock can never catch up; such requests wait for
        the pool to empty, where the frozen clock makes any chunk feasible).
        """
        clock = self._clock
        chunk = int(self.cfg.prefill_chunk or 0)
        s = max(1, longest - clock + 1)
        if chunk > 1:
            s = max(s, -(-(clock - 1) // (chunk - 1)))
        elif clock + s - 1 > s:
            return None
        return clock + s - 1

    def _ensure_pool(self, lg) -> None:
        if self._cache is None:
            self._cache = self.fresh(self.max_slots)
            self._logits = jnp.zeros((self.max_slots, lg.shape[-1]),
                                     lg.dtype)

    def admit(self, chosen, slot_ids, clock: int, params) -> None:
        """Monolithic admission: prefill ``chosen`` left-padded to
        ``clock`` on a side cache and scatter the rows into the pool.
        Blocks until the device work is done (callers time around it)."""
        k = len(chosen)
        tokens = np.full((k, clock), self.cfg.pad_id, np.int32)
        for j, (_, r) in enumerate(chosen):
            tokens[j, clock - len(r.prompt):] = np.asarray(r.prompt)
        tmp_cache = self.side_cache(k)
        lg, tmp_cache = self.eng._prefill(
            params, {"tokens": jnp.asarray(tokens)}, tmp_cache)
        self._ensure_pool(lg)
        idx = jnp.asarray(np.asarray(slot_ids[:k], np.int32))
        self._cache, self._logits = self.eng._admit_rows(
            self._cache, tmp_cache, self._logits, lg, idx)
        jax.block_until_ready(self._logits)
        self._clock = clock

    def scatter(self, pending) -> None:
        """A completed chunked admission joins the pool: scatter its
        side-cache rows and final-token logits at the committed clock."""
        self._ensure_pool(pending.logits)
        idx = jnp.asarray(np.asarray(pending.slot_ids, np.int32))
        self._cache, self._logits = self.eng._admit_rows(
            self._cache, pending.cache, self._logits, pending.logits, idx)
        jax.block_until_ready(self._logits)
        self._clock = pending.target

    # -------------------------------------------------------------- decode
    def decode(self, params, nxt, active_ids) -> None:
        self._logits, self._cache = self.eng._decode(
            params, nxt[:, None], self._cache)
        self._clock += 1

    def retire(self, slot_id: int) -> None:
        """Contiguous rows are recycled implicitly (masked by position)."""

    def stats(self) -> Dict[str, Any]:
        return {"backend": self.backend, "clock": self._clock}


# ---------------------------------------------------------------------------
# paged backend
# ---------------------------------------------------------------------------

class PagedKVCache(KVCache):
    """Block-pool KV cache with per-slot block tables, prefix sharing and
    copy-on-write. See the module docstring for the design; the invariants:

    * every physical block is in exactly one of {free list, cached set
      (ref == 0, registered, evictable), active (ref > 0)}, plus the
      reserved trash block — asserted by ``stats()`` consumers;
    * registered blocks are immutable: any admission or decode write into
      a block another slot might read lands on a private copy (COW);
    * an admitted slot can always finish: its remaining decode blocks are
      reserved (``reserved`` outstanding count) and allocation draws from
      the free list, then evicts cached blocks FIFO.
    """

    backend = "paged"

    def __init__(self, engine):
        super().__init__(engine)
        cfg = self.cfg
        self.block_size = cfg.block_size
        self.nb_per_slot = cfg.max_len // cfg.block_size
        self.num_blocks = cfg.kv_blocks or \
            (self.max_slots * self.nb_per_slot + 1)
        # host-authoritative paging state (pushed to device per decode)
        self._tables = np.full((self.max_slots, self.nb_per_slot), TRASH,
                               np.int32)
        self._lengths = np.zeros((self.max_slots,), np.int32)
        self._ref = np.zeros((self.num_blocks,), np.int32)
        self._free: List[int] = list(range(self.num_blocks - 1, TRASH, -1))
        self._cached: Dict[int, None] = {}     # ref==0 registered, FIFO
        self._slot_reserved = np.zeros((self.max_slots,), np.int32)
        self._reserved = 0
        # shared-prefix blocks pinned by an in-flight admission, held OUT
        # of the slot's table until commit: decode writes every batch row
        # at its position, and an in-flight slot sits at position 0 — its
        # table must stay all-TRASH (writes land in the trash block) so a
        # pinned REGISTERED block is never written through mid-admission
        self._pending_pins: Dict[int, List[int]] = {}
        # prefix registry: chain hash -> (phys, block tokens) for full
        # blocks (content-verified on match), parent hash -> (phys, fill,
        # tokens) for one partial tail per chain position
        self._full_map: Dict[int, Tuple[int, tuple]] = {}
        self._hash_of: Dict[int, int] = {}
        self._partial_map: Dict[int, Tuple[int, int, tuple]] = {}
        self._phys_partial: Dict[int, int] = {}
        # observability
        self.prefix_hits = 0
        self.prefix_tokens_reused = 0
        self.cow_copies = 0
        self.evictions = 0
        self.peak_blocks_active = 0
        # jitted paged ops with trace accounting (lazy counters: the
        # contiguous path's trace_counts stay exactly as before)
        for name in ("gather", "scatter", "copy"):
            engine.trace_counts.setdefault(name, 0)
        self._gather = engine._jit_counted("gather", gather_blocks)
        self._scatter = engine._jit_counted("scatter", scatter_blocks)
        self._copy = engine._jit_counted("copy", copy_block)
        # multi-position verifier forward (speculative decoding only —
        # the counter is lazy so non-speculative paged runs keep their
        # exact trace_counts dict)
        if getattr(cfg, "speculative", False):
            for name in ("verify", "spec_carry"):
                engine.trace_counts.setdefault(name, 0)
            model = self.model

            # the whole cycle tail is fused into the verify dispatch:
            # concat [t0, drafts], the S-position forward, and the
            # verifier's own argmax verdict — one device call and ONE
            # host sync (drafts+verdict together) per cycle, which is
            # where speculation's smoke-scale throughput win lives
            def verify_fused(params, t0, drafts, cache):
                x = jnp.concatenate([t0[:, None], drafts], axis=1)
                lg, cache = model.verify_step(params, x, cache)
                verdict = jnp.argmax(lg[:, :-1], axis=-1).astype(jnp.int32)
                return lg, verdict, cache

            def install_rows(logits, lg, slots, rows):
                return logits.at[slots].set(
                    lg[slots, rows].astype(logits.dtype))

            self._verify = engine._jit_counted("verify", verify_fused)
            self._install = engine._jit_counted("spec_carry", install_rows)

    # ---------------------------------------------------------------- clock
    @property
    def clock(self) -> Optional[int]:
        """Paged slots have per-slot positions, not a shared clock."""
        return None

    def check_request(self, req) -> None:
        need = -(-(len(req.prompt) + req.max_new_tokens) // self.block_size)
        if need > self.num_blocks - 1:
            raise ValueError(
                f"request {req.request_id}: prompt + max_new_tokens needs "
                f"{need} KV blocks but the pool only has "
                f"{self.num_blocks - 1} allocatable blocks")

    # ------------------------------------------------------ block lifecycle
    def _alloc(self) -> int:
        """A fresh writable block (ref=1): free list first, then FIFO
        eviction of cached (ref==0, registered) prefix blocks."""
        if self._free:
            ph = self._free.pop()
        elif self._cached:
            ph = next(iter(self._cached))
            del self._cached[ph]
            h = self._hash_of.pop(ph)
            self._full_map.pop(h, None)
            ent = self._partial_map.pop(h, None)
            if ent is not None:
                self._phys_partial.pop(ent[0], None)
            self.evictions += 1
        else:
            raise RuntimeError(
                "paged KV pool exhausted despite admission reservation")
        self._ref[ph] = 1
        active = self.num_blocks - 1 - len(self._free) - len(self._cached)
        self.peak_blocks_active = max(self.peak_blocks_active, active)
        return ph

    def _pin(self, ph: int) -> None:
        if self._ref[ph] == 0:
            self._cached.pop(ph, None)
        self._ref[ph] += 1

    def _unref(self, ph: int) -> None:
        self._ref[ph] -= 1
        assert self._ref[ph] >= 0
        if self._ref[ph] == 0:
            if ph in self._hash_of:
                self._cached[ph] = None      # reclaimable, keeps its hash
            else:
                h = self._phys_partial.pop(ph, None)
                if h is not None:
                    self._partial_map.pop(h, None)
                self._free.append(ph)

    # ------------------------------------------------------- prefix lookup
    def _lookup(self, prompt) -> Tuple[List[int], Optional[Tuple[int, int]]]:
        """Longest registered prefix of ``prompt``: full blocks (capped so
        at least one suffix token remains to prefill) plus at most one
        partial tail share ``(phys, fill)`` whose values seed the side
        cache (the block itself is COWed by the suffix write)."""
        bs = self.block_size
        L = len(prompt)
        h = 0
        full: List[int] = []
        j = 0
        while (j + 1) * bs <= L - 1:
            blk = tuple(prompt[j * bs:(j + 1) * bs])
            h2 = hash((h, blk))
            ent = self._full_map.get(h2)
            if ent is None or ent[1] != blk:
                break
            full.append(ent[0])
            h = h2
            j += 1
        partial = None
        ent = self._partial_map.get(h)
        if ent is not None:
            ph, fill, toks = ent
            f = min(fill, (L - 1) - j * bs)
            if f > 0 and tuple(prompt[j * bs:j * bs + f]) == toks[:f]:
                partial = (ph, f)
        if partial is None and bs > 1 and (j + 1) * bs == L:
            # the prompt's own last block is registered in full but the
            # keep-one-suffix cap excludes it — share all but its last
            # position (classic identical-prompt case; triggers COW)
            blk = tuple(prompt[j * bs:L])
            ent = self._full_map.get(hash((h, blk)))
            if ent is not None and ent[1] == blk:
                partial = (ent[0], bs - 1)
        return full, partial

    def _register(self, prompt, table) -> None:
        bs = self.block_size
        L = len(prompt)
        h = 0
        for j in range(L // bs):
            blk = tuple(prompt[j * bs:(j + 1) * bs])
            h = hash((h, blk))
            if h not in self._full_map:
                ph = int(table[j])
                self._full_map[h] = (ph, blk)
                self._hash_of[ph] = h
        f = L % bs
        if f and h not in self._partial_map:
            ph = int(table[L // bs])
            if ph not in self._phys_partial and ph not in self._hash_of:
                self._partial_map[h] = (ph, f, tuple(prompt[L - f:L]))
                self._phys_partial[ph] = h

    def on_weight_swap(self) -> None:
        """Cached prefix K/V were computed under the outgoing weights —
        flush the registry (in-use shared blocks keep their refs; parked
        blocks go back to the free list)."""
        for ph in list(self._cached):
            self._free.append(ph)
        self._cached.clear()
        for ph in list(self._hash_of):
            del self._hash_of[ph]
        self._full_map.clear()
        self._partial_map.clear()
        self._phys_partial.clear()

    # ----------------------------------------------------------- admission
    def pick(self, queue, nfree: int, fresh: bool, limit_head: bool
             ) -> Tuple[List, Optional[int]]:
        """FCFS-with-skip under a conservative block budget: a request is
        admissible iff its full ``ceil((L + max_new)/bs)`` block need fits
        in free + evictable blocks net of outstanding reservations (prefix
        sharing only *reduces* the real need at admit time)."""
        bs = self.block_size
        avail = len(self._free) + len(self._cached) - self._reserved
        chosen: List = []
        items = [queue[0]] if (limit_head and not fresh) else list(queue)
        for item in items:
            if len(chosen) >= nfree:
                break
            _, r = item
            need = -(-(len(r.prompt) + r.max_new_tokens) // bs)
            if need <= avail:
                chosen.append(item)
                avail -= need
        for item in chosen:
            queue.remove(item)
        return chosen, None

    def admit(self, chosen, slot_ids, clock, params) -> None:
        """Admit each request into its slot: prefix lookup → gather shared
        blocks → prefill the unshared suffix (batch 1, *unpadded* — the
        same shapes as a solo round, so greedy tokens are bit-identical to
        the contiguous oracle at equal effective context) → allocate/COW →
        scatter the written blocks into the pool."""
        for (_, r), slot in zip(chosen, slot_ids):
            self._admit_one(slot, r, params)
        jax.block_until_ready(self._logits)

    def _ensure_pool(self, lg) -> None:
        if self._cache is None:
            # the pool inherits the config's KV dtype (int8 codes +
            # per-(position, kv-head) scale pools under quantize_kv) —
            # hardcoding quantize_kv=False here silently scattered fp side
            # caches into an fp pool while ``fresh()``/``side_cache()``
            # honored the flag, which is exactly the dtype split the
            # regression test in tests/test_kvcache_paged.py pins down
            self._cache = self.model.init_cache(
                self.num_blocks, self.block_size,
                quantize_kv=self.cfg.quantize_kv)
            self._logits = jnp.zeros((self.max_slots, lg.shape[-1]),
                                     lg.dtype)

    def _pin_prefix(self, slot: int, prompt) -> Tuple[int, dict]:
        """The start of every paged admission (monolithic or chunked):
        longest-registered-prefix lookup, pin the matched blocks into the
        slot's table (ref++ — they leave the evictable cached set HERE,
        before any prefill work runs, so pool pressure during a multi-step
        admission can never recycle a block the admission is about to
        read), and gather their values into a fresh 1-row side cache whose
        clock is the shared length ``lp``.

        The pinned blocks are parked in ``_pending_pins`` — NOT written
        into the slot's table until :meth:`_commit_blocks`: decode writes
        every batch row's K/V at its position, and an in-flight slot sits
        at position 0 with its table all-TRASH, so interleaved resident
        decode steps land in the trash block instead of writing through a
        pinned registered block."""
        bs = self.block_size
        full, partial = self._lookup(prompt)
        nfull = len(full)
        f_part = partial[1] if partial else 0
        lp = nfull * bs + f_part
        pinned = list(full)
        if partial:
            pinned.append(partial[0])
        for ph in pinned:
            self._pin(ph)
        self._pending_pins[slot] = pinned
        side = self.side_cache(1)
        if lp:
            side = self._gather(side, self._cache,
                                jnp.asarray(np.asarray(pinned, np.int32)))
            side["pos"] = jnp.asarray(np.int32(lp))
            self.prefix_hits += 1
            self.prefix_tokens_reused += lp
        return lp, side

    def _commit_blocks(self, slot: int, prompt, lp: int, side, lg,
                       max_new: int) -> int:
        """The end of every paged admission: allocate / copy-on-write the
        write-range blocks, scatter the side-cache rows into them, place
        the last-token logits, register the prompt's blocks for prefix
        reuse, and set the slot's decode position to its own prompt
        length. Returns the decode-only block remainder (the reservation
        that stays outstanding until decode crosses those boundaries)."""
        bs = self.block_size
        L = len(prompt)
        self._ensure_pool(lg)
        table = self._tables[slot]
        for j, ph in enumerate(self._pending_pins.pop(slot, ())):
            table[j] = ph
        nb_prompt = -(-L // bs)
        first_wb = lp // bs
        for j in range(first_wb, nb_prompt):
            ph = int(table[j])
            if ph != TRASH:
                # shared (or registered) block in the write range: the
                # slot gets a private copy before its first divergent
                # write; the side cache already holds the shared values,
                # so the scatter below materializes the copy
                self._unref(ph)
                self.cow_copies += 1
            table[j] = self._alloc()
        phys_w = jnp.asarray(table[first_wb:nb_prompt].copy())
        self._cache = self._scatter(self._cache, side, phys_w,
                                    jnp.asarray(np.int32(first_wb)))
        self._logits = self._logits.at[slot].set(
            lg[0].astype(self._logits.dtype))
        self._register(prompt, table)
        self._lengths[slot] = L
        return -(-(L + max_new) // bs) - nb_prompt

    def _admit_one(self, slot: int, r, params) -> None:
        prompt = [int(t) for t in r.prompt]
        lp, side = self._pin_prefix(slot, prompt)
        if lp:
            toks = jnp.asarray(np.asarray(prompt[lp:], np.int32))[None]
            lg, side = self.eng._prefill_chunk(params, {"tokens": toks},
                                               side)
        else:
            toks = jnp.asarray(np.asarray(prompt, np.int32))[None]
            lg, side = self.eng._prefill(params, {"tokens": toks}, side)
        rem = self._commit_blocks(slot, prompt, lp, side, lg,
                                  r.max_new_tokens)
        self._slot_reserved[slot] = rem
        self._reserved += rem

    # ----------------------------------------- chunked (multi-step) admission
    def reserve_pending(self, slot: int, req) -> None:
        """Reserve a chunked admission's FULL block budget at pending
        creation, before its first chunk runs: ``pick`` chose the request
        against free + evictable net of reservations, and residents keep
        decoding (and allocating at block boundaries) for the whole
        multi-step admission — without the outstanding reservation their
        allocations could consume the blocks the pending needs to land.
        Completion re-points the reservation at the decode-only remainder;
        abandonment releases it."""
        need = -(-(len(req.prompt) + req.max_new_tokens) // self.block_size)
        self._slot_reserved[slot] = need
        self._reserved += need

    def begin_chunked_admit(self, slot: int, req) -> Tuple[int, dict]:
        """First chunk step of a pending entry: prefix lookup + pin +
        gather (see :meth:`_pin_prefix` — pinning happens HERE, before any
        chunk is consumed, never at completion: FIFO eviction of ref-0
        cached blocks under pool pressure between chunk steps could
        otherwise free a block the pending gathered from). Returns the
        shared-prefix length and the positioned side cache; the scheduler
        chunk-prefills ``prompt[lp:]`` on it across engine steps."""
        return self._pin_prefix(slot, [int(t) for t in req.prompt])

    def complete_chunked_admit(self, slot: int, req, lp: int, side,
                               lg) -> None:
        """A pending entry consumed its whole suffix: scatter the side
        cache into the slot's blocks at the slot's OWN prompt length (the
        per-slot clock — no shared completion clock, no catch-up
        recurrence) and re-point the up-front reservation at the
        decode-only remainder."""
        rem = self._commit_blocks(slot, [int(t) for t in req.prompt], lp,
                                  side, lg, req.max_new_tokens)
        resv = int(self._slot_reserved[slot])
        self._reserved -= resv - rem
        self._slot_reserved[slot] = rem
        jax.block_until_ready(self._logits)

    def abandon_chunked_admit(self, slot: int) -> None:
        """A force-swap abandons a pending entry mid-prefill: unpin
        (ref--) the shared-prefix blocks it pinned at begin and release
        the slot's reserved-block budget. Dropping only the side cache —
        the contiguous abandon path — would leak both until pool
        exhaustion."""
        for ph in self._pending_pins.pop(slot, ()):
            self._unref(ph)
        self.retire(slot)

    def check_invariants(self) -> None:
        """Test/debug hook: every non-trash block is in exactly one of
        {free, cached, active (ref > 0)} — i.e. free + cached + active +
        trash == num_blocks — reservations are non-negative and sum
        consistently, and every live table entry holds a reference."""
        free, cached = set(self._free), set(self._cached)
        assert TRASH not in free and TRASH not in cached
        assert not free & cached, "block in free AND cached"
        active = {ph for ph in range(1, self.num_blocks)
                  if self._ref[ph] > 0}
        assert not active & free and not active & cached, \
            "referenced block in free/cached"
        assert len(free) + len(cached) + len(active) + 1 == self.num_blocks
        assert all(self._ref[ph] == 0 for ph in free | cached)
        assert self._reserved == int(self._slot_reserved.sum()) >= 0
        assert np.all(self._slot_reserved >= 0)
        for pins in self._pending_pins.values():
            assert all(self._ref[ph] >= 1 for ph in pins), \
                "in-flight admission pin on unreferenced block"
        for s in range(self.max_slots):
            for j in range(self.nb_per_slot):
                ph = int(self._tables[s, j])
                assert ph == TRASH or self._ref[ph] >= 1, \
                    f"slot {s} table points at unreferenced block {ph}"

    # -------------------------------------------------------------- decode
    def _writable_block(self, i: int, j: int) -> None:
        """Make table entry ``(i, j)`` privately writable: allocate a
        reserved block at a TRASH boundary (drawing down the slot's
        reservation), or copy-on-write a block with other sharers
        (defensive at decode time: admission already privatizes every
        block it writes, so a shared tail here means a new sharing mode —
        keep the invariant regardless)."""
        ph = int(self._tables[i, j])
        if ph == TRASH:
            self._tables[i, j] = self._alloc()
            self._slot_reserved[i] -= 1
            self._reserved -= 1
        elif self._ref[ph] > 1:
            nb = self._alloc()
            self._cache = self._copy(self._cache,
                                     jnp.asarray(np.int32(ph)),
                                     jnp.asarray(np.int32(nb)))
            self._unref(ph)
            self._tables[i, j] = nb
            self.cow_copies += 1

    def decode(self, params, nxt, active_ids) -> None:
        bs = self.block_size
        for i in active_ids:
            self._writable_block(i, int(self._lengths[i]) // bs)
        # snapshots, not views: the device arrays may alias host memory
        # (zero-copy transfer) and ``_lengths``/``_tables`` are mutated
        # right after dispatch — aliasing would race the async decode
        self._cache["pos"] = jnp.asarray(self._lengths.copy())
        self._cache["block_tables"] = jnp.asarray(self._tables.copy())
        self._logits, self._cache = self.eng._decode(
            params, nxt[:, None], self._cache)
        self._lengths[active_ids] += 1

    # ------------------------------------------------- speculative verify
    def ensure_rows(self, slot: int, start: int, n: int) -> None:
        """Make positions ``start .. start+n-1`` of ``slot`` writable
        before a multi-position verify: allocate reserved blocks at TRASH
        boundaries exactly as decode does, and privatize (COW) any block
        with other sharers in the write range."""
        bs = self.block_size
        for j in range(start // bs, -(-(start + n) // bs)):
            self._writable_block(slot, j)

    def verify(self, params, t0, drafts, active_ids):
        """One batched multi-position verifier forward over ``[t0,
        drafts]``: slot ``b`` writes K/V for — and scores — absolute
        positions ``lengths[b] .. lengths[b]+S-1``, where ``S = 1 +
        drafts.shape[1]`` and row ``j`` of the returned ``(max_slots, S,
        vocab)`` logits conditions on everything through position
        ``lengths[b]+j``. Also returns the fused per-row argmax
        ``verdict`` (``(max_slots, S-1)``): ``verdict[b, j]`` is the
        token verifier-only decode would emit after ``[t0, d_1..d_j]``.
        Active slots' lengths advance by S (the speculative cycle
        rewinds the rejected suffix); inactive slots' tables are
        all-TRASH so their writes land in the trash block, exactly as in
        lockstep decode."""
        s = int(drafts.shape[1]) + 1
        for i in active_ids:
            self.ensure_rows(i, int(self._lengths[i]), s)
        self._cache["pos"] = jnp.asarray(self._lengths.copy())
        self._cache["block_tables"] = jnp.asarray(self._tables.copy())
        lg, verdict, self._cache = self._verify(params, t0, drafts,
                                                self._cache)
        self._lengths[np.asarray(active_ids, np.int64)] += s
        return lg, verdict

    def carry_logits(self, lg, slot_ids, rows) -> None:
        """Install ``lg[slot, rows[slot]]`` as each listed slot's pending
        logits — the verifier row at the divergence point, carried into
        the scheduler's next sample — in one fused gather+scatter."""
        self._logits = self._install(
            self._logits, lg,
            jnp.asarray(np.asarray(slot_ids, np.int32)),
            jnp.asarray(np.asarray(rows, np.int32)))

    def rewind(self, slot_id: int, n: int) -> None:
        """Roll ``slot_id`` back ``n`` positions (reject drafted tokens).

        A block that no longer holds any live position returns to the
        slot's *reservation* (``_slot_reserved``), never to another
        slot's budget: the slot drew down its reservation when it
        allocated the block and needs the claim back to finish its
        ``max_new_tokens``. The physical block itself goes through
        ``_unref`` — an exclusively-owned unregistered block lands on the
        free list (where the restored reservation keeps it claimable),
        a registered one parks in the cached set, and a block other slots
        still share just drops this slot's ref — so the free/cached/
        active partition and the ``free + cached - reserved`` admission
        budget both stay consistent."""
        if n <= 0:
            return
        new_len = int(self._lengths[slot_id]) - n
        assert new_len >= 0, "rewind past the start of the slot"
        for j in range(-(-new_len // self.block_size), self.nb_per_slot):
            ph = int(self._tables[slot_id, j])
            if ph != TRASH:
                self._unref(ph)
                self._tables[slot_id, j] = TRASH
                self._slot_reserved[slot_id] += 1
                self._reserved += 1
        self._lengths[slot_id] = new_len

    def retire(self, slot_id: int) -> None:
        """Drop the slot's refs; exclusively-owned unregistered blocks go
        back to the free list, registered ones park in the cached set."""
        for j in range(self.nb_per_slot):
            ph = int(self._tables[slot_id, j])
            if ph != TRASH:
                self._unref(ph)
                self._tables[slot_id, j] = TRASH
        self._lengths[slot_id] = 0
        self._reserved -= int(self._slot_reserved[slot_id])
        self._slot_reserved[slot_id] = 0

    # ------------------------------------------------------- observability
    def block_bytes(self) -> int:
        """Device bytes per physical block (all layers)."""
        if self._cache is None:
            return 0
        total = 0
        for key in ("periods", "list"):
            if key in self._cache:
                total += sum(l.nbytes for l in
                             jax.tree_util.tree_leaves(self._cache[key]))
        return total // self.num_blocks

    def bytes_per_position(self) -> int:
        """Device KV bytes per cached position (all layers) — the unit
        decode attention's HBM traffic scales with: each step reads the
        slot's whole context at this rate. int8 pools pay
        ``2*D + 2*itemsize(scale)`` per (position, kv-head, layer) vs
        ``2*D*itemsize`` for fp pools."""
        return self.block_bytes() // self.block_size if self.block_size \
            else 0

    def pool_bytes(self) -> int:
        """Total device bytes held by the block pool (all layers)."""
        return self.block_bytes() * self.num_blocks

    def stats(self) -> Dict[str, Any]:
        free, cached = len(self._free), len(self._cached)
        return {"backend": self.backend,
                "quantize_kv": self.cfg.quantize_kv,
                "block_size": self.block_size,
                "bytes_per_position": self.bytes_per_position(),
                "pool_bytes": self.pool_bytes(),
                "blocks_total": self.num_blocks,
                "blocks_free": free,
                "blocks_cached": cached,
                "blocks_active": self.num_blocks - 1 - free - cached,
                "blocks_trash": 1,
                "blocks_reserved": self._reserved,
                "peak_blocks_active": self.peak_blocks_active,
                "block_bytes": self.block_bytes(),
                "prefix_hits": self.prefix_hits,
                "prefix_tokens_reused": self.prefix_tokens_reused,
                "cow_copies": self.cow_copies,
                "evictions": self.evictions}
