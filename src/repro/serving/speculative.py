"""Self-speculative decoding: the w4 quantization drafts for the w8 verifier.

SQuant's on-the-fly, data-free quantization produces *multiple* bit-widths
of one checkpoint essentially for free (sub-second, no data, no BP), which
turns the quantization ladder into a speculative-decoding ladder: the
serving tree (e.g. w8) is the verifier, and a lower-bit tree
(``ServeConfig.draft_bits``, default w4) of the SAME checkpoint is the
drafter. Both trees are staged and swapped atomically as one
:class:`~repro.serving.weights.WeightVersion` pair.

Per continuous-scheduler step (paged backend only — per-slot positions and
:meth:`PagedKVCache.rewind` are required):

1. the scheduler samples the carry token ``t0`` from the verifier's
   pending logits, exactly as in verifier-only decode;
2. the draft tree autoregressively proposes ``k_eff <= draft_k`` tokens
   ``d_1..d_k`` on its own contiguous draft cache (device-side argmax
   chaining — no host sync per draft token);
3. the verifier scores all ``k_eff + 1`` positions ``[t0, d_1..d_k]`` in
   ONE batched multi-position forward (``LM.verify_step`` on the paged
   pool — row ``j`` reproduces bit-exactly the logits a lockstep decode
   step at that position would emit);
4. the longest prefix of drafts matching the verifier's own argmax is
   accepted; the rejected suffix is rolled back (``kv.rewind``) and the
   verifier row at the divergence point becomes the next pending logits —
   so the next ``t0`` is exactly the token verifier-only decode would
   have produced there.

Greedy acceptance therefore makes the emitted token stream **bit-identical
to verifier-only decode**: every emitted token is either verified-argmax-
equal to a draft, or the verifier's own argmax. Speculation changes only
the steps-per-token (and the host-sync count per token), never the tokens.

The draft cache is a plain contiguous ``(max_slots, max_len)`` cache with
*per-slot* positions (paged slots are not left-padded or lockstepped), fed
through the vector-position decode path in :mod:`repro.models.attention`.
Draft rewind is position-only: stale draft rows past the accepted length
are masked by position and overwritten by later proposals.
"""
from __future__ import annotations

import collections
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SpeculativeDecoder"]


class SpeculativeDecoder:
    """Draft-side state and the draft/verify device plumbing for one
    continuous-scheduler run. The scheduler keeps slot bookkeeping
    (emission, EOS/budget retirement, Completion assembly); this object
    owns the draft cache, the chain/verify calls, and the acceptance
    arithmetic."""

    def __init__(self, engine, kv):
        self.eng = engine
        self.cfg = engine.cfg
        self.model = engine.model
        self.kv = kv                      # the PagedKVCache (verifier side)
        self.max_slots = kv.max_slots
        self.draft_k = int(self.cfg.draft_k)
        # host-authoritative draft positions, pushed per chain call
        self.draft_lengths = np.zeros((self.max_slots,), np.int32)
        self._draft_cache = None          # contiguous (max_slots, max_len)
        self._chain_fns: Dict[int, Any] = {}   # k_eff -> jitted chain
        # lazy trace counters: non-speculative runs keep their exact
        # trace_counts dict (tests assert equality on the baseline keys)
        for name in ("draft_prefill", "draft_chain", "draft_admit"):
            engine.trace_counts.setdefault(name, 0)
        self._draft_prefill = engine._jit_counted("draft_prefill",
                                                  self.model.prefill)
        self._draft_admit = engine._jit_counted("draft_admit",
                                                _admit_draft_rows)
        # observability (surfaced through SchedulerStats)
        self.cycles = 0
        self.proposed = 0
        self.accepted = 0
        self.accepted_len_log: collections.deque = \
            collections.deque(maxlen=4096)

    # ------------------------------------------------------------ admission
    def _ensure_cache(self) -> None:
        if self._draft_cache is None:
            # fp cache regardless of quantize_kv (speculative is gated off
            # quantize_kv anyway; the drafter only needs self-consistency)
            self._draft_cache = self.model.init_cache(
                self.max_slots, self.cfg.max_len, quantize_kv=False)

    def admit_slot(self, slot: int, prompt, draft_params) -> None:
        """Prefill the slot's prompt on the draft tree (batch 1, unpadded
        — the same shapes as the paged admission prefill) and scatter the
        rows into the slot's row of the draft cache. The prefill's logits
        are discarded: the chain always starts from the verifier-sampled
        carry token, never from a draft-tree sample."""
        self._ensure_cache()
        side = self.model.init_cache(1, self.cfg.max_len, quantize_kv=False)
        side["pos"] = jnp.zeros((), jnp.int32)
        toks = jnp.asarray(np.asarray([int(t) for t in prompt], np.int32))
        _, side = self._draft_prefill(draft_params, {"tokens": toks[None]},
                                      side)
        self._draft_cache = self._draft_admit(
            self._draft_cache, side, jnp.asarray(np.int32(slot)))
        self.draft_lengths[slot] = len(prompt)

    def retire_slot(self, slot: int) -> None:
        """Stale draft rows are masked by position; only the position
        needs resetting (a later admission re-prefills the row)."""
        self.draft_lengths[slot] = 0

    # ---------------------------------------------------------------- chain
    def _chain_fn(self, steps: int):
        """A jitted draft chain for ``steps`` proposals: ``steps + 1``
        decode feeds — the extra feed writes the LAST proposal's K/V row
        (its logits are discarded), so a fully-accepted run leaves no gap
        in the draft cache for the next cycle to trip over."""
        fn = self._chain_fns.get(steps)
        if fn is not None:
            return fn
        model = self.model

        def chain(params, cache, t0):
            tok = t0                          # (B,) int32
            drafts = []
            for _ in range(steps):
                lg, cache = model.decode_step(params, tok[:, None], cache)
                tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                drafts.append(tok)
            _, cache = model.decode_step(params, tok[:, None], cache)
            return jnp.stack(drafts, axis=1), cache

        fn = self.eng._jit_counted("draft_chain", chain)
        self._chain_fns[steps] = fn
        return fn

    def propose(self, draft_params, t0, k_eff: int):
        """Run the draft chain: returns the ``(max_slots, k_eff)`` int32
        proposals. Rows of slots outside the speculating set produce
        garbage drafts into their own (position-masked) cache rows and
        are simply ignored by the caller."""
        self._ensure_cache()
        self._draft_cache["pos"] = jnp.asarray(self.draft_lengths.copy())
        drafts, self._draft_cache = self._chain_fn(k_eff)(
            draft_params, self._draft_cache, t0)
        return drafts

    # ---------------------------------------------------------------- cycle
    def run_cycle(self, params, draft_params, t0, alive: List[int]):
        """One draft→verify cycle for the ``alive`` slots.

        ``t0``: the ``(max_slots,)`` carry tokens the scheduler just
        sampled (and recorded). Returns ``(k_eff, accept, drafts_np,
        verify_logits)`` where ``accept[i]`` is the per-slot count of
        verifier-matching draft tokens (0..k_eff), ``drafts_np`` is the
        ``(max_slots, k_eff)`` proposal matrix, and ``verify_logits`` is
        the device ``(max_slots, k_eff+1, vocab)`` verifier output —
        row ``accept[i]`` of slot ``i`` is the pending-logits carry for
        the next scheduler step.

        The verifier's lengths advance by ``k_eff + 1`` inside
        ``kv.verify``; the CALLER rewinds survivors by ``k_eff -
        accept[i]`` (and retires finished slots), keeping all slot
        lifecycle in the scheduler."""
        # uniform chain depth, clamped so no slot's verify writes can run
        # past its reserved blocks (budget >= 1 for every alive slot)
        k_eff = min([self.draft_k] + [self._budget(i) for i in alive])
        drafts = self.propose(draft_params, t0, k_eff)
        # fused verify: [t0, drafts] concat, the (B, k+1, V) forward and
        # the per-row verdict argmax all run in ONE dispatch, and the
        # cycle pays ONE host sync for drafts + verdict together
        lg, verdict = self.kv.verify(params, t0, drafts, alive)
        # the drafter's feeds advanced every row's draft position by
        # k_eff + 1; survivors are resynced to the verifier length by the
        # scheduler after rewind (see sync_slot)
        self.draft_lengths += k_eff + 1
        drafts_np, verdict_np = jax.device_get((drafts, verdict))
        match = drafts_np == verdict_np
        # longest matching prefix: index of first mismatch (or k_eff)
        accept = np.where(match.all(axis=1), k_eff,
                          np.argmin(match, axis=1))
        self.cycles += 1
        self.proposed += k_eff * len(alive)
        return k_eff, accept, drafts_np, lg

    def _budget(self, slot: int) -> int:
        """Remaining token budget of an alive slot (>= 1 by the caller's
        retirement invariant) — the cap that keeps verify writes inside
        the slot's reserved blocks."""
        s = self._sched.slots[slot]
        return s.req.max_new_tokens - len(s.tokens)

    def bind(self, scheduler) -> None:
        self._sched = scheduler

    def sync_slot(self, slot: int) -> None:
        """After the scheduler rewound the verifier, mirror the accepted
        length into the draft clock (draft rewind is position-only)."""
        self.draft_lengths[slot] = int(self.kv._lengths[slot])

    def stats(self) -> Dict[str, Any]:
        al = np.asarray(self.accepted_len_log, np.float64)
        tail = {f"p{q}": float(np.percentile(al, q)) for q in (50, 95)} \
            if al.size else {}
        return {"spec_cycles": self.cycles,
                "draft_tokens_proposed": self.proposed,
                "draft_tokens_accepted": self.accepted,
                "acceptance_rate": (self.accepted / self.proposed
                                    if self.proposed else 0.0),
                "accepted_len": tail}


def _admit_draft_rows(pool, side, slot):
    """Scatter the 1-row draft prefill cache into row ``slot`` of the
    draft pool (batch-leading leaves at axis 0, scan-stacked period leaves
    at axis 1). ``pos`` is host-managed and left untouched."""
    out = dict(pool)

    def r0(a, b):
        return a.at[slot].set(b[0].astype(a.dtype))

    def r1(a, b):
        return a.at[:, slot].set(b[:, 0].astype(a.dtype))

    for key in pool:
        if key == "pos":
            continue
        out[key] = jax.tree_util.tree_map(
            r1 if key == "periods" else r0, pool[key], side[key])
    return out
