"""Tolerance-equivalence harness: teacher-forced greedy-token agreement.

(Methodology, measured per-architecture numbers, and a how-to for adding
new budgets live in ``docs/equivalence.md``; the machine-enforced support
surface is rendered to ``docs/support-matrix.md`` by
``scripts/gen_support_matrix.py``.)

The serving test story started as bit-identity: chunked == monolithic,
paged == contiguous, continuous == round, all asserted token-for-token.
Some features break bit-identity by construction — int8 KV codes perturb
every attention read; a sliding-window ring chunk permutes the key axis;
MoE capacity competition depends on how a prefill is chunked; mamba/rwkv
chunk continuations regroup the prefix scan — so configs carrying them
are held to a *measured agreement budget* instead, in the spirit of the
mixtral 0.041 serving-divergence budget the weight path already uses.

The metric is **teacher-forced greedy-token agreement**: run the fp oracle
engine once to get its greedy continuation per request, then run the
config under test with the scheduler's ``token_override`` hook forcing the
oracle's token into each slot after sampling. Every step therefore asks
"given the oracle's exact context, does this config's argmax match?" —
per-step conditional agreement, with no divergence compounding (one early
flip would otherwise make every later comparison meaningless). The rate
is ``matched / compared`` across all requests and positions.

Budgets are keyed per feature — serve-config features (``int8_kv``) and
architecture features (``mla``, ``sliding_window``, ``moe``, ``mamba``,
``rwkv``; see :func:`repro.models.model.arch_features`) — and **compose
multiplicatively** when features stack: each feature's flips are
independent perturbations of the same argmax, so a config carrying two
features owes at least the product of their floors (mixtral under chunked
prefill owes ``sliding_window * moe``; add ``quantize_kv`` and it owes
``int8_kv`` on top). Floors are enforced in tests
(``tests/test_chunked_archs.py``) and in ``scripts/check_bench.py``
(the ``kv_bytes`` and ``chunked_archs`` gates, measured by
``benchmarks/bench_serving.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["AGREEMENT_BUDGETS", "AgreementReport", "active_budget_keys",
           "agreement_budget", "greedy_token_agreement", "oracle_tokens"]

# Hard floors on teacher-forced greedy agreement vs the fp oracle, keyed
# by the feature that breaks bit-identity. A config with no active key
# owes exact tokens (budget 1.0 — the existing identity tests). The
# architecture floors are measured by the ``chunked_archs`` ladder in
# ``benchmarks/bench_serving.py`` (committed to BENCH_serving.json) and
# set below the worst measurement with margin; "mla" measured exact at
# fp32 serving widths, so it owes identity.
AGREEMENT_BUDGETS: Dict[str, float] = {
    "int8_kv": 0.98,
    "exact": 1.0,
    # architecture keys, active while chunk-continuation prefill is in
    # play (prefill_chunk > 0, or the paged backend's shared-prefix
    # suffix continuation)
    "mla": 1.0,
    "sliding_window": 0.95,
    "moe": 0.85,
    "mamba": 0.95,
    "rwkv": 0.95,
}


def active_budget_keys(cfg, arch_cfg=None) -> List[str]:
    """The ``AGREEMENT_BUDGETS`` keys a (ServeConfig, architecture) pair
    activates. Serve-config keys are always considered; architecture keys
    only apply when chunk-continuation prefill can run — ``prefill_chunk
    > 0``, or the paged backend (whose shared-prefix admission continues
    a suffix prefill at an offset even with ``prefill_chunk == 0``).
    ``arch_cfg=None`` (legacy single-argument callers) checks the
    serve-config keys only."""
    keys: List[str] = []
    if cfg.quantize_kv:
        keys.append("int8_kv")
    if arch_cfg is not None and (cfg.prefill_chunk > 0
                                 or cfg.kv_backend == "paged"):
        from repro.models.model import arch_features
        keys.extend(arch_features(arch_cfg))
    return keys


def agreement_budget(cfg, arch_cfg=None) -> float:
    """The agreement floor a (ServeConfig, architecture) pair owes vs the
    fp oracle: the **product** of every active feature floor (features
    perturb the argmax independently, so stacked features owe the product
    — a single-key lookup would silently hand e.g. ``int8_kv x moe`` the
    wrong floor). No active keys → exact (1.0)."""
    budget = 1.0
    for key in active_budget_keys(cfg, arch_cfg):
        budget *= AGREEMENT_BUDGETS[key]
    return budget


@dataclasses.dataclass
class AgreementReport:
    matched: int
    compared: int
    per_request: Dict[int, Tuple[int, int]]   # rid -> (matched, compared)

    @property
    def rate(self) -> float:
        return 1.0 if self.compared == 0 else self.matched / self.compared

    def assert_budget(self, budget: float, label: str = "") -> None:
        if self.rate < budget:
            worst = sorted(self.per_request.items(),
                           key=lambda kv: kv[1][0] / max(kv[1][1], 1))[:3]
            raise AssertionError(
                f"greedy-token agreement {self.rate:.4f} < budget "
                f"{budget:.2f}{' (' + label + ')' if label else ''}; "
                f"worst requests {worst} "
                f"({self.matched}/{self.compared} matched)")


def oracle_tokens(completions) -> Dict[int, List[int]]:
    """Completion list → {request_id: greedy tokens} (the oracle side)."""
    return {c.request_id: list(c.tokens) for c in completions}


def greedy_token_agreement(engine, requests: Sequence,
                           oracle: Dict[int, List[int]]
                           ) -> AgreementReport:
    """Teacher-forced agreement of ``engine`` (continuous scheduler) vs an
    oracle's greedy tokens.

    Installs the scheduler's ``token_override`` hook for the duration of
    one ``generate(requests)`` call: at every sampling step the engine's
    proposed token is compared against — then replaced by — the oracle's
    token at that position, so the engine's KV cache always holds the
    oracle's continuation and each comparison is conditionally
    independent. Requests absent from ``oracle`` (or positions past its
    tokens) run free and are not counted.
    """
    sch = engine.scheduler
    if not hasattr(sch, "token_override"):
        raise ValueError(
            "greedy_token_agreement requires the continuous scheduler "
            "(the round scheduler has no token_override hook)")
    matched = 0
    compared = 0
    per: Dict[int, Tuple[int, int]] = {}

    def override(rid: int, t: int, proposed: int) -> Optional[int]:
        nonlocal matched, compared
        toks = oracle.get(rid)
        if toks is None or t >= len(toks):
            return None
        hit = int(proposed == toks[t])
        m, n = per.get(rid, (0, 0))
        per[rid] = (m + hit, n + 1)
        matched += hit
        compared += 1
        return int(toks[t])

    prev = sch.token_override
    sch.token_override = override
    try:
        engine.generate(list(requests))
    finally:
        sch.token_override = prev
    return AgreementReport(matched, compared, per)
