"""Tolerance-equivalence harness (first slice): greedy-token agreement.

The serving test story so far has been bit-identity: chunked == monolithic,
paged == contiguous, continuous == round, all asserted token-for-token.
Quantized KV caches break that by construction — int8 codes with
per-(token, head) scales perturb every attention read — so configs with
``quantize_kv=True`` are held to a *per-config agreement budget* instead,
in the spirit of the mixtral 0.041 serving-divergence budget the weight
path already uses.

The metric is **teacher-forced greedy-token agreement**: run the fp oracle
engine once to get its greedy continuation per request, then run the
config under test with the scheduler's ``token_override`` hook forcing the
oracle's token into each slot after sampling. Every step therefore asks
"given the oracle's exact context, does this config's argmax match?" —
per-step conditional agreement, with no divergence compounding (one early
flip would otherwise make every later comparison meaningless). The rate
is ``matched / compared`` across all requests and positions.

Budgets are per config-feature, hard floors enforced both here (tests)
and in ``scripts/check_bench.py`` (the ``kv_bytes`` gate). Next expansion
(see ROADMAP): per-architecture budgets so MLA / MoE / recurrent mixers
can lift their chunked-prefill gates on the same contract.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["AGREEMENT_BUDGETS", "AgreementReport", "agreement_budget",
           "greedy_token_agreement", "oracle_tokens"]

# hard floors on teacher-forced greedy agreement vs the fp oracle, keyed
# by the config feature that breaks bit-identity. A config with no such
# feature owes exact tokens (budget 1.0 — the existing identity tests).
AGREEMENT_BUDGETS: Dict[str, float] = {
    "int8_kv": 0.98,
    "exact": 1.0,
}


def agreement_budget(cfg) -> float:
    """The agreement floor a ServeConfig owes vs the fp oracle."""
    return AGREEMENT_BUDGETS["int8_kv"] if cfg.quantize_kv \
        else AGREEMENT_BUDGETS["exact"]


@dataclasses.dataclass
class AgreementReport:
    matched: int
    compared: int
    per_request: Dict[int, Tuple[int, int]]   # rid -> (matched, compared)

    @property
    def rate(self) -> float:
        return 1.0 if self.compared == 0 else self.matched / self.compared

    def assert_budget(self, budget: float, label: str = "") -> None:
        if self.rate < budget:
            worst = sorted(self.per_request.items(),
                           key=lambda kv: kv[1][0] / max(kv[1][1], 1))[:3]
            raise AssertionError(
                f"greedy-token agreement {self.rate:.4f} < budget "
                f"{budget:.2f}{' (' + label + ')' if label else ''}; "
                f"worst requests {worst} "
                f"({self.matched}/{self.compared} matched)")


def oracle_tokens(completions) -> Dict[int, List[int]]:
    """Completion list → {request_id: greedy tokens} (the oracle side)."""
    return {c.request_id: list(c.tokens) for c in completions}


def greedy_token_agreement(engine, requests: Sequence,
                           oracle: Dict[int, List[int]]
                           ) -> AgreementReport:
    """Teacher-forced agreement of ``engine`` (continuous scheduler) vs an
    oracle's greedy tokens.

    Installs the scheduler's ``token_override`` hook for the duration of
    one ``generate(requests)`` call: at every sampling step the engine's
    proposed token is compared against — then replaced by — the oracle's
    token at that position, so the engine's KV cache always holds the
    oracle's continuation and each comparison is conditionally
    independent. Requests absent from ``oracle`` (or positions past its
    tokens) run free and are not counted.
    """
    sch = engine.scheduler
    if not hasattr(sch, "token_override"):
        raise ValueError(
            "greedy_token_agreement requires the continuous scheduler "
            "(the round scheduler has no token_override hook)")
    matched = 0
    compared = 0
    per: Dict[int, Tuple[int, int]] = {}

    def override(rid: int, t: int, proposed: int) -> Optional[int]:
        nonlocal matched, compared
        toks = oracle.get(rid)
        if toks is None or t >= len(toks):
            return None
        hit = int(proposed == toks[t])
        m, n = per.get(rid, (0, 0))
        per[rid] = (m + hit, n + 1)
        matched += hit
        compared += 1
        return int(toks[t])

    prev = sch.token_override
    sch.token_override = override
    try:
        engine.generate(list(requests))
    finally:
        sch.token_override = prev
    return AgreementReport(matched, compared, per)
