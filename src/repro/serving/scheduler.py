"""Slot schedulers for the serving engine: the static round scheduler and
the reload-aware continuous-batching scheduler.

Scheduling model
----------------
Model caches keep ONE scalar decode position (``cache["pos"]``) for the
whole batch, so every sequence in a batch decodes in lockstep at a shared
clock. Both schedulers build on that invariant:

* :class:`RoundScheduler` — the original static batching: requests are
  grouped into rounds of up to ``max_batch``, left-padded to the round's
  longest prompt, and decoded in lockstep until every request in the round
  finishes. Prefill/cache/decode are sized to the *actual* round batch
  (padding rows to ``max_batch`` bought nothing: every serving op is
  row-independent, so jit retraces happen per distinct batch size either
  way, and smaller rounds now allocate proportionally smaller KV caches —
  asserted retrace-free across same-shape rounds in tests).

* :class:`ContinuousScheduler` — a fixed pool of ``max_slots`` decode slots
  backed by ONE persistent KV cache (slot = cache row). Queued requests are
  admitted into free slots at step boundaries by left-padding the prompt to
  the current clock ``P`` (prompt occupies positions ``P-L..P-1`` — exactly
  the round engine's left-padding semantics, applied per slot instead of
  per round); the admission prefill runs on a small side cache whose rows
  are scattered into the pool. Slots retire on EOS/max-tokens immediately,
  so short requests never wait on long ones. Because every serving op is
  row-independent, a slot's greedy tokens are bit-identical to what the
  round engine would produce for the same request at the same padding
  (``tests/test_scheduler.py``).

Reload-awareness (the point): when the :class:`~repro.serving.weights.
WeightStore` reports a fully-staged version, the continuous scheduler stops
admitting, drains in-flight slots, and performs the atomic swap at a step
boundary — or force-swaps after ``swap_deadline_ms`` of draining, in which
case in-flight slots finish on the new weights (their KV cache remains
valid: it holds activations, not weight state, and ``Completion.
forced_swaps`` records the event). Admission then resumes (refill). The
round engine can swap only between rounds, i.e. after its *longest*
in-flight request finishes — the decode-dip ``benchmarks/bench_serving.py``
measures.

Clock horizon: a slot admitted at clock ``P`` with budget ``m`` writes KV
up to position ``P+m-1``, so admission requires ``P + m <= max_len``. The
clock resets to 0 whenever the pool empties (a fresh wave re-uses the pool
cache; rows at/after the new clock are masked by position, rows before it
are rewritten by the wave's prefill).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampling import sample


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new_tokens: int = 16
    request_id: int = 0


@dataclasses.dataclass
class Completion:
    request_id: int
    tokens: List[int]
    prefill_ms: float
    decode_ms: float
    swap_ms: float = 0.0          # weight-swap time observed by this request
    weights_version: int = 1      # WeightStore version pinned at admission
    forced_swaps: int = 0         # deadline force-swaps that landed in flight


def admit_rows(pool, tmp, pool_logits, tmp_logits, idx):
    """Scatter a ``k``-row prefill cache + its last-token logits into the
    ``max_slots``-row pool at slot indices ``idx``.

    Cache leaves are batch-leading except scan-stacked period caches
    (``(periods, batch, ...)`` — batch at axis 1) and the scalar ``pos``,
    which the admission prefill computed for the new clock and which simply
    replaces the pool's (both equal the clock while slots are in flight; on
    a fresh wave it rewinds the pool).
    """
    out = dict(pool)

    def rows0(a, b):
        return a.at[idx].set(b.astype(a.dtype))

    def rows1(a, b):
        return a.at[:, idx].set(b.astype(a.dtype))

    for key in pool:
        if key == "pos":
            continue
        out[key] = jax.tree_util.tree_map(
            rows1 if key == "periods" else rows0, pool[key], tmp[key])
    out["pos"] = tmp["pos"]
    return out, pool_logits.at[idx].set(tmp_logits.astype(pool_logits.dtype))


@dataclasses.dataclass
class _Slot:
    order: int                    # index into the run()'s request list
    req: Request
    version: int                  # weight version pinned at admission
    clock0: int                   # clock (= padded prompt length) at admission
    t0: float                     # perf_counter right after admission prefill
    prefill_ms: float
    swap_ms: float = 0.0
    forced_swaps: int = 0
    tokens: List[int] = dataclasses.field(default_factory=list)


class _SchedulerBase:
    def __init__(self, engine):
        self.eng = engine
        self.cfg = engine.cfg
        self.model = engine.model
        self.store = engine.store
        self.steps_total = 0

    def _emit_step(self, info: Dict[str, Any]) -> None:
        step_log = getattr(self, "step_log", None)
        if step_log is not None:
            step_log.append(info)
        if self.eng.on_step is not None:
            self.eng.on_step(info)

    def _validate(self, req: Request) -> None:
        """Both schedulers share one cache horizon: a request needs
        ``len(prompt) + max_new_tokens`` positions. Oversized requests
        would otherwise clamp ``dynamic_update_slice`` writes onto the
        last cache row and silently corrupt decode."""
        n_prompt = len(req.prompt)
        if n_prompt + req.max_new_tokens > self.cfg.max_len:
            raise ValueError(
                f"request {req.request_id}: prompt ({n_prompt}) + "
                f"max_new_tokens ({req.max_new_tokens}) exceeds "
                f"max_len ({self.cfg.max_len})")


# ---------------------------------------------------------------------------
# round scheduler (static batching)
# ---------------------------------------------------------------------------

class RoundScheduler(_SchedulerBase):
    """Static batching: FCFS rounds of up to ``max_batch``; a round ends
    only when its longest request does. Swaps land between rounds."""

    name = "round"

    def __init__(self, engine):
        super().__init__(engine)
        self.step_log: Optional[List[Dict[str, Any]]] = None

    def run(self, requests: List[Request]) -> List[Completion]:
        out: List[Completion] = []
        reqs = list(requests)
        for r in reqs:
            self._validate(r)
        while reqs:
            out.extend(self._run_round(reqs[:self.cfg.max_batch]))
            reqs = reqs[self.cfg.max_batch:]
        return out

    def stats(self) -> Dict[str, Any]:
        return {"kind": self.name, "steps": self.steps_total,
                "rounds": self.eng._rounds_total}

    def _run_round(self, reqs: List[Request]) -> List[Completion]:
        cfg = self.cfg
        # the ONLY swap point: in-flight rounds hold `ver` to the end
        ver, swap_ms = self.store.acquire()
        params = ver.params
        # sized to the actual round: a 2-request round on an 8-slot config
        # allocates a 2-row cache. Trade-off vs the old pad-to-max_batch
        # loop: rounds of the same (b, plen) shape never retrace (asserted
        # in tests via engine.trace_counts), but each NEW partial-round
        # size compiles its own decode trace — submit full rounds (or use
        # the continuous scheduler, whose decode shape is fixed at
        # max_slots) when that latency matters more than cache memory.
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        tokens = np.full((b, plen), cfg.pad_id, np.int32)
        for i, r in enumerate(reqs):
            tokens[i, plen - len(r.prompt):] = np.asarray(r.prompt)

        cache = self.model.init_cache(b, cfg.max_len,
                                      quantize_kv=cfg.quantize_kv)
        batch = {"tokens": jnp.asarray(tokens)}
        if self.model.cfg.is_encdec:
            batch["enc_frames"] = jnp.zeros(
                (b, max(1, plen // self.model.cfg.enc_ratio),
                 self.model.cfg.d_model), jnp.float32)
        t0 = time.perf_counter()
        logits, cache = self.eng._prefill(params, batch, cache)
        jax.block_until_ready(logits)
        prefill_ms = (time.perf_counter() - t0) * 1e3

        max_new = max(r.max_new_tokens for r in reqs)
        produced = np.full((b, max_new), cfg.pad_id, np.int32)
        done = np.zeros(b, bool)
        t0 = time.perf_counter()
        for t in range(max_new):
            self.eng._key, sk = jax.random.split(self.eng._key)
            nxt = sample(logits, sk, cfg.temperature, cfg.top_k)
            nxt_np = np.asarray(nxt)
            recorded = 0
            for i, r in enumerate(reqs):
                if not done[i] and t < r.max_new_tokens:
                    produced[i, t] = nxt_np[i]
                    recorded += 1
                    if nxt_np[i] == cfg.eos_id:
                        done[i] = True
                else:
                    done[i] = done[i] or t >= r.max_new_tokens
            self.steps_total += 1
            self._emit_step({"step": self.steps_total, "recorded": recorded,
                             "version": ver.version, "draining": False,
                             "t": time.perf_counter()})
            if all(done[i] for i in range(b)):
                break
            logits, cache = self.eng._decode(params, nxt[:, None], cache)
        jax.block_until_ready(logits)
        decode_ms = (time.perf_counter() - t0) * 1e3

        # the round ran start-to-finish on `ver`; a version staged mid-round
        # becomes visible only to the next acquire() (asserted in tests)
        self.eng._rounds_total += 1
        self.eng._round_log.append({"version": ver.version,
                                    "prefill_ms": prefill_ms,
                                    "decode_ms": decode_ms,
                                    "swap_ms": swap_ms,
                                    "requests": b})

        outs = []
        for i, r in enumerate(reqs):
            toks = [int(x) for x in produced[i, :r.max_new_tokens]]
            # truncate at EOS
            if cfg.eos_id >= 0 and cfg.eos_id in toks:
                toks = toks[:toks.index(cfg.eos_id) + 1]
            outs.append(Completion(r.request_id, toks, prefill_ms,
                                   decode_ms, swap_ms, ver.version))
        return outs


# ---------------------------------------------------------------------------
# continuous scheduler (slot pool + reload-aware drain/refill)
# ---------------------------------------------------------------------------

class ContinuousScheduler(_SchedulerBase):
    """Continuous batching over a fixed slot pool with one persistent KV
    cache; admission at step boundaries, per-slot retirement, and
    drain-then-swap (deadline-bounded) around weight reloads."""

    name = "continuous"

    def __init__(self, engine):
        super().__init__(engine)
        if self.model.cfg.is_encdec:
            raise NotImplementedError(
                "continuous scheduler does not support encoder-decoder "
                "models yet (per-slot encoder outputs have admission-"
                "dependent lengths); use scheduler='round'")
        self.max_slots = self.cfg.max_slots or self.cfg.max_batch
        self.slots: List[Optional[_Slot]] = [None] * self.max_slots
        self._cache = None            # persistent pool cache (lazy init)
        self._logits = None           # (max_slots, vocab) pending logits
        self._pending_swap_ms = 0.0   # swap time to attribute at admission
        # observability
        self.admitted = 0
        self.retired = 0
        self.drains = 0
        self.forced_swaps = 0
        self.waves = 0
        self.occupancy_sum = 0
        self.max_occupancy = 0
        self.step_log: Optional[List[Dict[str, Any]]] = None
        # bounded: one entry per admission, observable padding/version
        self.admission_log: collections.deque = \
            collections.deque(maxlen=1024)

    # ------------------------------------------------------------------ api
    def run(self, requests: List[Request]) -> List[Completion]:
        cfg = self.cfg
        results: List[Optional[Completion]] = [None] * len(requests)
        queue: "collections.deque[Tuple[int, Request]]" = collections.deque()
        ver, swap_ms = self.store.acquire()
        params = ver.params
        self._pending_swap_ms += swap_ms
        for i, r in enumerate(requests):
            self._validate(r)
            if r.max_new_tokens <= 0:
                results[i] = Completion(r.request_id, [], 0.0, 0.0, 0.0,
                                        ver.version)
                continue
            queue.append((i, r))
        clock = 0
        drain_t0 = None

        while queue or any(s is not None for s in self.slots):
            active_ids = [i for i, s in enumerate(self.slots)
                          if s is not None]
            # ---- reload-awareness: drain, then swap at a step boundary ----
            staged = self.store.staged_info()
            if staged is not None:
                if drain_t0 is None:
                    drain_t0 = time.perf_counter()
                    self.drains += 1
                    self.store.note_drain(len(active_ids))
                elapsed_ms = (time.perf_counter() - drain_t0) * 1e3
                deadline = cfg.swap_deadline_ms
                # the deadline clock starts when the version finished
                # staging (store-side), not when this loop first saw it —
                # a version staged between generate() calls swaps at once
                if not active_ids or (deadline is not None
                                      and staged["age_ms"] >= deadline):
                    forced = bool(active_ids)
                    ver, sms = self.store.acquire()
                    params = ver.params
                    self.store.note_swap(forced=forced, drain_ms=elapsed_ms)
                    self._pending_swap_ms += sms
                    if forced:
                        self.forced_swaps += 1
                        for i in active_ids:
                            self.slots[i].forced_swaps += 1
                            self.slots[i].swap_ms += sms
                    drain_t0 = None
            draining = self.store.staged_pending

            # ---- admission into free slots (paused while draining) ----
            free_ids = [i for i, s in enumerate(self.slots) if s is None]
            if queue and free_ids and not draining:
                fresh = len(free_ids) == self.max_slots
                chosen, new_clock = self._pick(queue, clock,
                                               len(free_ids), fresh)
                if chosen:
                    if fresh:
                        self.waves += 1
                    clock = new_clock
                    self._admit(chosen, free_ids, clock, params, ver.version)

            active_ids = [i for i, s in enumerate(self.slots)
                          if s is not None]
            if not active_ids:
                # only reachable while draining paused admission with an
                # empty pool; the swap branch fires on the next iteration
                continue

            # ---- one lockstep step: sample at `clock`, retire, decode ----
            self.eng._key, sk = jax.random.split(self.eng._key)
            nxt = sample(self._logits, sk, cfg.temperature, cfg.top_k)
            nxt_np = np.asarray(nxt)
            recorded = 0
            t_now = time.perf_counter()
            for i in active_ids:
                s = self.slots[i]
                tok = int(nxt_np[i])
                s.tokens.append(tok)
                recorded += 1
                if (len(s.tokens) >= s.req.max_new_tokens
                        or (cfg.eos_id >= 0 and tok == cfg.eos_id)):
                    results[s.order] = Completion(
                        s.req.request_id, s.tokens, s.prefill_ms,
                        (t_now - s.t0) * 1e3, s.swap_ms, s.version,
                        s.forced_swaps)
                    self.slots[i] = None
                    self.retired += 1
            self.steps_total += 1
            self.occupancy_sum += recorded
            self.max_occupancy = max(self.max_occupancy, recorded)
            self._emit_step({"step": self.steps_total, "clock": clock,
                             "recorded": recorded, "version": ver.version,
                             "draining": draining, "t": t_now})
            if any(s is not None for s in self.slots):
                self._logits, self._cache = self.eng._decode(
                    params, nxt[:, None], self._cache)
                clock += 1
        return results  # type: ignore[return-value]

    def stats(self) -> Dict[str, Any]:
        return {"kind": self.name, "max_slots": self.max_slots,
                "steps": self.steps_total, "admitted": self.admitted,
                "retired": self.retired, "waves": self.waves,
                "drains": self.drains, "forced_swaps": self.forced_swaps,
                "mean_occupancy": (self.occupancy_sum / self.steps_total
                                   if self.steps_total else 0.0),
                "max_occupancy": self.max_occupancy}

    # ------------------------------------------------------------ internals
    def _pick(self, queue, clock: int, nfree: int, fresh: bool):
        """Choose up to ``nfree`` queued requests admissible at the clock.

        Mid-flight (``fresh=False``): FCFS with skip — a request fits iff
        its prompt fits under the clock (``L <= clock``; the clock advances
        one position per step, so longer prompts become admissible soon)
        and its budget fits the cache horizon.

        Fresh wave (``fresh=True``): the pool is empty, so the clock
        restarts at the wave's longest admitted prompt. The queue head is
        always admitted (its own ``L + max_new <= max_len`` was validated
        at submit), guaranteeing progress; growing the wave re-checks every
        already-chosen request against the raised clock so admission never
        invalidates an earlier choice.
        """
        max_len = self.cfg.max_len
        chosen: List[Tuple[int, Request]] = []
        new_clock = 0 if fresh else clock
        for item in list(queue):
            if len(chosen) >= nfree:
                break
            _, r = item
            if fresh:
                cand = max(new_clock, len(r.prompt))
                if (cand + r.max_new_tokens <= max_len
                        and all(cand + c.max_new_tokens <= max_len
                                for _, c in chosen)):
                    chosen.append(item)
                    new_clock = cand
            else:
                if (len(r.prompt) <= clock
                        and clock + r.max_new_tokens <= max_len):
                    chosen.append(item)
        for item in chosen:
            queue.remove(item)
        return chosen, new_clock

    def _admit(self, chosen, free_ids, clock: int, params, version: int):
        """Prefill ``chosen`` left-padded to ``clock`` on a side cache and
        scatter the rows into the pool at the first ``len(chosen)`` free
        slots."""
        cfg = self.cfg
        k = len(chosen)
        tokens = np.full((k, clock), cfg.pad_id, np.int32)
        for j, (_, r) in enumerate(chosen):
            tokens[j, clock - len(r.prompt):] = np.asarray(r.prompt)
        tmp_cache = self.model.init_cache(k, cfg.max_len,
                                          quantize_kv=cfg.quantize_kv)
        t0 = time.perf_counter()
        lg, tmp_cache = self.eng._prefill(
            params, {"tokens": jnp.asarray(tokens)}, tmp_cache)
        if self._cache is None:
            self._cache = self.model.init_cache(
                self.max_slots, cfg.max_len, quantize_kv=cfg.quantize_kv)
            self._logits = jnp.zeros((self.max_slots, lg.shape[-1]),
                                     lg.dtype)
        idx = jnp.asarray(np.asarray(free_ids[:k], np.int32))
        self._cache, self._logits = self.eng._admit_rows(
            self._cache, tmp_cache, self._logits, lg, idx)
        jax.block_until_ready(self._logits)
        prefill_ms = (time.perf_counter() - t0) * 1e3
        t_now = time.perf_counter()
        for j, (order, r) in enumerate(chosen):
            self.slots[free_ids[j]] = _Slot(
                order=order, req=r, version=version, clock0=clock,
                t0=t_now, prefill_ms=prefill_ms,
                swap_ms=self._pending_swap_ms)
            self.admission_log.append(
                {"request_id": r.request_id, "slot": free_ids[j],
                 "clock": clock, "version": version})
        self._pending_swap_ms = 0.0
        self.admitted += k
