"""Slot schedulers for the serving engine: the static round scheduler and
the reload-aware continuous-batching scheduler.

Scheduling model
----------------
Model caches keep ONE scalar decode position (``cache["pos"]``) for the
whole batch, so every sequence in a batch decodes in lockstep at a shared
clock. Both schedulers build on that invariant:

* :class:`RoundScheduler` — the original static batching: requests are
  grouped into rounds of up to ``max_batch``, left-padded to the round's
  longest prompt, and decoded in lockstep until every request in the round
  finishes. Prefill/cache/decode are sized to the *actual* round batch
  (padding rows to ``max_batch`` bought nothing: every serving op is
  row-independent, so jit retraces happen per distinct batch size either
  way, and smaller rounds now allocate proportionally smaller KV caches —
  asserted retrace-free across same-shape rounds in tests).

* :class:`ContinuousScheduler` — a fixed pool of ``max_slots`` decode slots
  backed by ONE persistent KV cache (slot = cache row). Queued requests are
  admitted into free slots at step boundaries by left-padding the prompt to
  the current clock ``P`` (prompt occupies positions ``P-L..P-1`` — exactly
  the round engine's left-padding semantics, applied per slot instead of
  per round); the admission prefill runs on a small side cache whose rows
  are scattered into the pool. Slots retire on EOS/max-tokens immediately,
  so short requests never wait on long ones. Because every serving op is
  row-independent, a slot's greedy tokens are bit-identical to what the
  round engine would produce for the same request at the same padding
  (``tests/test_scheduler.py``).

Reload-awareness (the point): when the :class:`~repro.serving.weights.
WeightStore` reports a fully-staged version, the continuous scheduler stops
admitting, drains in-flight slots, and performs the atomic swap at a step
boundary — or force-swaps after ``swap_deadline_ms`` of draining, in which
case in-flight slots finish on the new weights (their KV cache remains
valid: it holds activations, not weight state, and ``Completion.
forced_swaps`` records the event). Admission then resumes (refill). The
round engine can swap only between rounds, i.e. after its *longest*
in-flight request finishes — the decode-dip ``benchmarks/bench_serving.py``
measures.

Clock horizon: a slot admitted at clock ``P`` with budget ``m`` writes KV
up to position ``P+m-1``, so admission requires ``P + m <= max_len``. The
clock resets to 0 whenever the pool empties (a fresh wave re-uses the pool
cache; rows at/after the new clock are masked by position, rows before it
are rewritten by the wave's prefill).

Chunked prefill (``ServeConfig.prefill_chunk > 0``): admission prefill is
the continuous scheduler's one unbounded step — a long-prompt admission
stalls every resident slot for the full prefill. With chunking on, an
admission becomes a :class:`PendingPrefill` that consumes at most
``prefill_chunk`` positions of its left-padded prompt per engine step on
the side cache (``LM.prefill_chunk`` continues from the partial cache)
while resident slots keep decoding; the rows are scattered into the pool
(the same ``admit_rows`` path) only when the prefill completes. Because
residents advance the clock one position per step while the pending
consumes ``chunk`` per step, the admission commits up front to the
completion clock ``P`` solving ``P = C0 + s - 1`` with ``s`` chunk-steps
covering ``P`` positions (``s*(chunk-1) >= C0-1``); the pending's prompt is
left-padded to that ``P``, so its greedy tokens are bit-identical to the
monolithic path admitted at the same padding (chunk continuation reuses the
prefill einsums; masked-out cache columns contribute exact zeros). With
``chunk == 1`` a mid-flight pending can never catch a moving clock, so such
admissions wait for the pool to empty (frozen clock) — fresh-wave chunking
works at any chunk size. Reload drains wait on pendings like any in-flight
work; a deadline force-swap *abandons* the pending (its chunks ran on the
old weights) and re-queues its requests at the front of the queue.

Under ``kv_backend="paged"`` the pending is a :class:`PagedPendingPrefill`:
no shared clock means no catch-up recurrence and no left-padding — each
entry's completion target is its own prompt length, so EVERY chunk size
works mid-flight (including ``chunk == 1``) and tokens are
position-deterministic regardless of admission timing. Entries chunk one
at a time on a 1-row side cache (shared-prefix blocks pinned + gathered
first, only the unshared suffix prefilled); a completed entry scatters
into its reserved blocks and starts decoding immediately while later
entries keep chunking. A force-swap abandon additionally releases the
unfinished entries' block reservations and prefix pins.

KV-cache ownership: cache state (allocation, the decode clock, admission
prefill + row/block scatter, retirement) lives behind the
:class:`repro.serving.kvcache.KVCache` API — ``ContiguousKVCache`` is the
layout described above; ``kv_backend="paged"`` swaps in ``PagedKVCache``
(block tables + prefix sharing + copy-on-write, no left-padding, no shared
clock). The schedulers only decide WHEN: admission timing, slot lifecycle,
drain/swap points, sampling.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Request/Completion moved to repro.serving.api (the deliberate public
# surface); these re-imports keep `scheduler.Request` working as a
# deprecated alias for existing callers
from repro.serving.api import Completion, Request, SchedulerStats
from repro.serving.kvcache import KVCache, admit_rows  # noqa: F401
from repro.serving.sampling import sample
from repro.serving.speculative import SpeculativeDecoder


def _req_eos(req: Request, cfg) -> int:
    """Per-request EOS override (None: the engine-global eos_id)."""
    return cfg.eos_id if req.eos_id is None else req.eos_id


@dataclasses.dataclass
class _Slot:
    order: int                    # index into the run()'s request list
    req: Request
    version: int                  # weight version pinned at admission
    clock0: int                   # clock (= padded prompt length) at admission
    t0: float                     # perf_counter right after admission prefill
    prefill_ms: float
    swap_ms: float = 0.0
    forced_swaps: int = 0
    tokens: List[int] = dataclasses.field(default_factory=list)
    steps: int = 0                # engine sampling steps this slot spanned
    proposed: int = 0             # speculative: draft tokens offered
    accepted: int = 0             # speculative: draft tokens kept


@dataclasses.dataclass
class PendingPrefill:
    """A chunked admission in flight: its prompt (left-padded to the
    committed completion clock) is consumed ``prefill_chunk`` positions per
    engine step on a side cache; only on completion are the rows scattered
    into the pool and slots created."""
    chosen: List[Tuple[int, Request]]   # (order, request) per row
    slot_ids: List[int]                 # reserved pool rows
    target: int                         # committed completion clock P
    version: int                        # weight version pinned at creation
    tokens: np.ndarray                  # (k, P) left-padded prompt matrix
    done: int = 0                       # positions consumed so far
    cache: Any = None                   # side cache (k rows), lazy init
    logits: Any = None                  # last chunk's final-token logits
    prefill_ms: float = 0.0             # accumulated chunk wall time
    chunks: int = 0

    @property
    def remaining(self) -> int:
        return self.target - self.done

    @property
    def remaining_requests(self) -> int:
        return len(self.chosen)


@dataclasses.dataclass
class PagedPendingPrefill:
    """A chunked admission on the paged backend. No shared clock, so no
    catch-up recurrence and no left-padding: each chosen request's
    completion target is its OWN prompt length. Entries are consumed
    strictly in admission order — the current entry's unshared suffix
    (shared-prefix blocks were pinned and gathered into the side cache
    before its first chunk) is chunk-prefilled on a 1-row side cache
    across engine steps, and on completion its rows are scattered into
    the slot's reserved blocks and the slot starts decoding immediately
    while later entries keep chunking. Every entry's full block budget is
    reserved at creation (``reserve_pending``) so resident decode
    allocations can never starve the in-flight admission."""
    chosen: List[Tuple[int, Request]]   # (order, request), consumed in order
    slot_ids: List[int]                 # reserved slot per entry
    version: int                        # weight version pinned at creation
    entry: int = 0                      # index of the in-progress entry
    lp: int = 0                         # entry's shared-prefix length
    done: int = 0                       # suffix positions consumed (entry)
    suffix: Any = None                  # entry's unshared suffix (np.int32)
    cache: Any = None                   # 1-row side cache (None: not begun)
    logits: Any = None                  # last chunk's final-token logits
    entry_ms: float = 0.0               # accumulated chunk wall time (entry)
    chunks: int = 0                     # chunk forwards issued (entry)

    @property
    def remaining_requests(self) -> int:
        return len(self.chosen) - self.entry


class _SchedulerBase:
    def __init__(self, engine):
        self.eng = engine
        self.cfg = engine.cfg
        self.model = engine.model
        self.store = engine.store
        # all cache state (allocation, clock, admission scatter, paging)
        # lives behind the KVCache API; schedulers never touch cache dicts
        self.kv = KVCache.create(engine)
        self.steps_total = 0

    def _emit_step(self, info: Dict[str, Any]) -> None:
        step_log = getattr(self, "step_log", None)
        if step_log is not None:
            step_log.append(info)
        if self.eng.on_step is not None:
            self.eng.on_step(info)

    def _validate(self, req: Request) -> None:
        """Both schedulers share one cache horizon: a request needs
        ``len(prompt) + max_new_tokens`` positions. Oversized requests
        would otherwise clamp ``dynamic_update_slice`` writes onto the
        last cache row and silently corrupt decode."""
        n_prompt = len(req.prompt)
        if n_prompt + req.max_new_tokens > self.cfg.max_len:
            raise ValueError(
                f"request {req.request_id}: prompt ({n_prompt}) + "
                f"max_new_tokens ({req.max_new_tokens}) exceeds "
                f"max_len ({self.cfg.max_len})")
        self.kv.check_request(req)


# ---------------------------------------------------------------------------
# round scheduler (static batching)
# ---------------------------------------------------------------------------

class RoundScheduler(_SchedulerBase):
    """Static batching: FCFS rounds of up to ``max_batch``; a round ends
    only when its longest request does. Swaps land between rounds."""

    name = "round"

    def __init__(self, engine):
        super().__init__(engine)
        self.step_log: Optional[List[Dict[str, Any]]] = None

    def run(self, requests: List[Request]) -> List[Completion]:
        out: List[Completion] = []
        reqs = list(requests)
        for r in reqs:
            self._validate(r)
        while reqs:
            out.extend(self._run_round(reqs[:self.cfg.max_batch]))
            reqs = reqs[self.cfg.max_batch:]
        return out

    def stats(self) -> SchedulerStats:
        return SchedulerStats(kind=self.name, steps=self.steps_total,
                              rounds=self.eng._rounds_total)

    def _run_round(self, reqs: List[Request]) -> List[Completion]:
        cfg = self.cfg
        # the ONLY swap point: in-flight rounds hold `ver` to the end
        ver, swap_ms = self.store.acquire()
        params = ver.params
        # sized to the actual round: a 2-request round on an 8-slot config
        # allocates a 2-row cache. Trade-off vs the old pad-to-max_batch
        # loop: rounds of the same (b, plen) shape never retrace (asserted
        # in tests via engine.trace_counts), but each NEW partial-round
        # size compiles its own decode trace — submit full rounds (or use
        # the continuous scheduler, whose decode shape is fixed at
        # max_slots) when that latency matters more than cache memory.
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        tokens = np.full((b, plen), cfg.pad_id, np.int32)
        for i, r in enumerate(reqs):
            tokens[i, plen - len(r.prompt):] = np.asarray(r.prompt)

        cache = self.kv.fresh(b)
        batch = {"tokens": jnp.asarray(tokens)}
        if self.model.cfg.is_encdec:
            batch["enc_frames"] = jnp.zeros(
                (b, max(1, plen // self.model.cfg.enc_ratio),
                 self.model.cfg.d_model), jnp.float32)
        t0 = time.perf_counter()
        logits, cache = self.eng._prefill(params, batch, cache)
        jax.block_until_ready(logits)
        prefill_ms = (time.perf_counter() - t0) * 1e3

        max_new = max(r.max_new_tokens for r in reqs)
        produced = np.full((b, max_new), cfg.pad_id, np.int32)
        done = np.zeros(b, bool)
        t0 = time.perf_counter()
        for t in range(max_new):
            self.eng._key, sk = jax.random.split(self.eng._key)
            nxt = sample(logits, sk, cfg.temperature, cfg.top_k)
            nxt_np = np.asarray(nxt)
            recorded = 0
            for i, r in enumerate(reqs):
                if not done[i] and t < r.max_new_tokens:
                    produced[i, t] = nxt_np[i]
                    recorded += 1
                    if nxt_np[i] == _req_eos(r, cfg):
                        done[i] = True
                else:
                    done[i] = done[i] or t >= r.max_new_tokens
            self.steps_total += 1
            self._emit_step({"step": self.steps_total, "recorded": recorded,
                             "version": ver.version, "draining": False,
                             "t": time.perf_counter()})
            if all(done[i] for i in range(b)):
                break
            logits, cache = self.eng._decode(params, nxt[:, None], cache)
        jax.block_until_ready(logits)
        decode_ms = (time.perf_counter() - t0) * 1e3

        # the round ran start-to-finish on `ver`; a version staged mid-round
        # becomes visible only to the next acquire() (asserted in tests)
        self.eng._rounds_total += 1
        self.eng._round_log.append({"version": ver.version,
                                    "prefill_ms": prefill_ms,
                                    "decode_ms": decode_ms,
                                    "swap_ms": swap_ms,
                                    "requests": b})

        outs = []
        for i, r in enumerate(reqs):
            toks = [int(x) for x in produced[i, :r.max_new_tokens]]
            # truncate at EOS
            eid = _req_eos(r, cfg)
            if eid >= 0 and eid in toks:
                toks = toks[:toks.index(eid) + 1]
            outs.append(Completion(r.request_id, toks, prefill_ms,
                                   decode_ms, swap_ms, ver.version,
                                   steps=len(toks)))
        return outs


# ---------------------------------------------------------------------------
# continuous scheduler (slot pool + reload-aware drain/refill)
# ---------------------------------------------------------------------------

class ContinuousScheduler(_SchedulerBase):
    """Continuous batching over a fixed slot pool with one persistent KV
    cache; admission at step boundaries, per-slot retirement, and
    drain-then-swap (deadline-bounded) around weight reloads."""

    name = "continuous"

    def __init__(self, engine):
        super().__init__(engine)
        # config-only feasibility (chunk >= 0, paged backend shape rules)
        # is validated by engine.CONFIG_GATES; model-dependent feasibility
        # (encoder-decoder x continuous, paged x non-positional caches) by
        # engine.ARCH_GATES — both run in ServeEngine.__init__ before this.
        # Chunked prefill itself is no longer gated on architecture: every
        # decoder-only mixer has a chunk-continuation path, serving under
        # its measured agreement budget (repro.serving.equivalence).
        self.chunk = int(self.cfg.prefill_chunk or 0)
        self.max_slots = self.kv.max_slots
        self.slots: List[Optional[_Slot]] = [None] * self.max_slots
        # self-speculative decoding: the draft-side state + device plumbing
        # (config feasibility — paged-only, greedy-only, no quantize_kv —
        # is validated by the CONFIG_GATES table)
        self.spec: Optional[SpeculativeDecoder] = None
        if self.cfg.speculative:
            self.spec = SpeculativeDecoder(engine, self.kv)
            self.spec.bind(self)
        self._ver = None              # the currently-acquired WeightVersion
        self._pending_swap_ms = 0.0   # swap time to attribute at admission
        self._kv_version = None       # weight version the KV prefix cache
        #                               was built under (flush on change)
        self._pending: Optional[PendingPrefill] = None
        self._head_skips = 0          # FCFS-with-skip starvation guard
        # tolerance-equivalence hook (repro.serving.equivalence): when set,
        # called per (request_id, position, proposed_token) right after
        # sampling; a non-None return replaces the token BOTH in the slot's
        # record and in the decode feed — teacher-forcing the oracle's
        # continuation so greedy-token agreement is measured per step
        # without divergence compounding
        self.token_override = None
        self._last_emit_t: Optional[float] = None
        # observability
        self.admitted = 0
        self.retired = 0
        self.drains = 0
        self.forced_swaps = 0
        self.waves = 0
        self.occupancy_sum = 0
        self.max_occupancy = 0
        self.chunk_steps = 0          # prefill-chunk forwards issued
        self.pendings_started = 0
        self.pendings_abandoned = 0   # force-swap abandoned chunked admits
        self.step_log: Optional[List[Dict[str, Any]]] = None
        # bounded: per-sampling-step wall time, feeds the stats() tail
        # percentiles (the metric chunked prefill exists to bound)
        self.step_ms_log: collections.deque = collections.deque(maxlen=4096)
        # bounded: one entry per admission, observable padding/version
        self.admission_log: collections.deque = \
            collections.deque(maxlen=1024)

    # ------------------------------------------------------------------ api
    def run(self, requests: List[Request]) -> List[Completion]:
        cfg = self.cfg
        results: List[Optional[Completion]] = [None] * len(requests)
        queue: "collections.deque[Tuple[int, Request]]" = collections.deque()
        ver, swap_ms = self.store.acquire()
        params = ver.params
        self._ver = ver
        # a version staged between generate() calls swaps at this acquire,
        # bypassing the drain branch — the KV cache must still learn of it
        self._sync_kv_version(ver.version)
        self._pending_swap_ms += swap_ms
        for i, r in enumerate(requests):
            self._validate(r)
            if r.max_new_tokens <= 0:
                results[i] = Completion(r.request_id, [], 0.0, 0.0, 0.0,
                                        ver.version)
                continue
            queue.append((i, r))
        self.kv.begin_run()
        drain_t0 = None
        self._last_emit_t = time.perf_counter()

        while queue or self._pending is not None \
                or any(s is not None for s in self.slots):
            active_ids = [i for i, s in enumerate(self.slots)
                          if s is not None]
            # ---- reload-awareness: drain, then swap at a step boundary ----
            staged = self.store.staged_info()
            if staged is not None:
                if drain_t0 is None:
                    drain_t0 = time.perf_counter()
                    self.drains += 1
                    in_flight = len(active_ids) + (
                        self._pending.remaining_requests
                        if self._pending else 0)
                    self.store.note_drain(in_flight)
                elapsed_ms = (time.perf_counter() - drain_t0) * 1e3
                deadline = cfg.swap_deadline_ms
                # the deadline clock starts when the version finished
                # staging (store-side), not when this loop first saw it —
                # a version staged between generate() calls swaps at once.
                # A chunked admission in flight is drained like any other
                # in-flight work; a forced swap abandons it instead (its
                # chunks ran on the old weights) and re-queues its requests
                busy = bool(active_ids) or self._pending is not None
                if not busy or (deadline is not None
                                and staged.age_ms >= deadline):
                    if self._pending is not None:
                        self._abandon_pending(queue)
                    forced = busy
                    ver, sms = self.store.acquire()
                    params = ver.params
                    self._ver = ver
                    self._sync_kv_version(ver.version)
                    self.store.note_swap(forced=forced, drain_ms=elapsed_ms)
                    self._pending_swap_ms += sms
                    if forced:
                        self.forced_swaps += 1
                        for i in active_ids:
                            self.slots[i].forced_swaps += 1
                            self.slots[i].swap_ms += sms
                    drain_t0 = None
            draining = self.store.staged_pending

            # ---- admission into free slots (paused while draining or
            # while a chunked admission is already in flight) ----
            admit_ms = 0.0
            if self._pending is None and queue and not draining:
                free_ids = [i for i, s in enumerate(self.slots)
                            if s is None]
                if free_ids:
                    fresh = len(free_ids) == self.max_slots
                    head = queue[0]
                    limit_head = (not fresh and self._head_skips
                                  >= cfg.starvation_limit)
                    if self.chunk:
                        chosen = self._start_pending(
                            queue, free_ids, fresh, ver.version, limit_head)
                    else:
                        chosen, new_clock = self.kv.pick(
                            queue, len(free_ids), fresh, limit_head)
                        if chosen:
                            if fresh:
                                self.waves += 1
                            t0 = time.perf_counter()
                            self._admit(chosen, free_ids, new_clock, params,
                                        ver.version)
                            admit_ms = (time.perf_counter() - t0) * 1e3
                    # FCFS-with-skip starvation guard: count picks that
                    # jumped the queue head; past the limit, mid-flight
                    # admission narrows to the head only, so the pool
                    # drains into a fresh wave that must admit it
                    if fresh or (chosen and head in chosen):
                        self._head_skips = 0
                    elif chosen:
                        self._head_skips += 1

            # ---- chunked admission: consume this step's prefill budget;
            # scatter into the pool when it completes at its clock ----
            chunk_ms = 0.0
            if self._pending is not None:
                chunk_ms = self._advance_pending(params)
                p = self._pending
                # paged pendings complete per-entry inside the advance (no
                # completion-clock rendezvous); only the contiguous pending
                # waits here for the shared clock to reach its target
                if isinstance(p, PendingPrefill) and p.done >= p.target \
                        and (self.kv.clock == p.target or not active_ids):
                    self._scatter_pending(p)

            active_ids = [i for i, s in enumerate(self.slots)
                          if s is not None]
            if not active_ids:
                # reachable while draining paused admission with an empty
                # pool (the swap branch fires next iteration) or while a
                # chunked admission is still consuming its prompt on an
                # empty pool (the clock is frozen; chunks run back-to-back)
                continue

            # ---- one lockstep step: sample, retire, decode ----
            self.eng._key, sk = jax.random.split(self.eng._key)
            nxt = sample(self.kv.logits, sk, cfg.temperature, cfg.top_k)
            nxt_np = np.asarray(nxt)
            if self.token_override is not None:
                nxt_np = nxt_np.copy()
                for i in active_ids:
                    s = self.slots[i]
                    ov = self.token_override(s.req.request_id,
                                             len(s.tokens),
                                             int(nxt_np[i]))
                    if ov is not None:
                        nxt_np[i] = ov
                nxt = jnp.asarray(nxt_np)
            recorded = 0
            t_now = time.perf_counter()
            step_ms = (t_now - self._last_emit_t) * 1e3
            self._last_emit_t = t_now
            self.step_ms_log.append(step_ms)
            for i in active_ids:
                s = self.slots[i]
                tok = int(nxt_np[i])
                s.tokens.append(tok)
                s.steps += 1
                recorded += 1
                eid = _req_eos(s.req, cfg)
                if (len(s.tokens) >= s.req.max_new_tokens
                        or (eid >= 0 and tok == eid)):
                    self._finish(results, i, t_now)
            self.steps_total += 1
            self.occupancy_sum += recorded
            self.max_occupancy = max(self.max_occupancy, recorded)
            self._emit_step({"step": self.steps_total,
                             "clock": self.kv.clock,
                             "recorded": recorded, "version": ver.version,
                             "draining": draining, "t": t_now,
                             "step_ms": step_ms, "chunk_ms": chunk_ms,
                             "admit_ms": admit_ms})
            alive = [i for i, s in enumerate(self.slots) if s is not None]
            if alive:
                if self.spec is not None:
                    # speculative cycle: the carry token's K/V row is
                    # written by the verify forward together with the
                    # draft run (there is no separate decode step)
                    self._spec_cycle(results, params, nxt, alive)
                else:
                    self.kv.decode(params, nxt, alive)
        return results  # type: ignore[return-value]

    def _finish(self, results, slot: int, t_now: float) -> None:
        """Retire slot ``slot`` and record its Completion."""
        s = self.slots[slot]
        results[s.order] = Completion(
            s.req.request_id, s.tokens, s.prefill_ms,
            (t_now - s.t0) * 1e3, s.swap_ms, s.version, s.forced_swaps,
            steps=s.steps, draft_tokens_proposed=s.proposed,
            draft_tokens_accepted=s.accepted)
        self.slots[slot] = None
        self.kv.retire(slot)
        if self.spec is not None:
            self.spec.retire_slot(slot)
        self.retired += 1

    def _spec_cycle(self, results, params, t0, alive: List[int]) -> None:
        """One self-speculative cycle for the ``alive`` slots (their carry
        tokens ``t0`` are already recorded): draft ``k_eff`` proposals,
        verify all ``k_eff + 1`` positions in one forward, emit the
        longest verifier-matching prefix per slot, rewind the rejected
        suffix, and install the divergence-row logits as the slot's
        pending logits — so the next sampled token is exactly what
        verifier-only decode would have produced."""
        cfg = self.cfg
        k_eff, accept, drafts, lg = self.spec.run_cycle(
            params, self._ver.draft_params, t0, alive)
        survivors: List[int] = []
        acc_rows: List[int] = []
        t_now = time.perf_counter()
        for i in alive:
            s = self.slots[i]
            a = int(accept[i])
            eid = _req_eos(s.req, cfg)
            emitted = 0
            retired = False
            for j in range(a):
                tok = int(drafts[i, j])
                s.tokens.append(tok)
                emitted += 1
                if (len(s.tokens) >= s.req.max_new_tokens
                        or (eid >= 0 and tok == eid)):
                    retired = True
                    break
            s.proposed += k_eff
            s.accepted += emitted
            self.spec.accepted += emitted
            self.spec.accepted_len_log.append(1 + emitted)
            if retired:
                self._finish(results, i, t_now)
            else:
                # verify advanced the slot by k_eff + 1; keep the carry
                # token + the accepted drafts, return the rest to the
                # slot's block reservation
                self.kv.rewind(i, k_eff - a)
                self.spec.sync_slot(i)
                survivors.append(i)
                acc_rows.append(a)
        if survivors:
            self.kv.carry_logits(lg, survivors, acc_rows)

    def stats(self) -> SchedulerStats:
        ms = np.asarray(self.step_ms_log, np.float64)
        tail = {f"p{q}": float(np.percentile(ms, q)) for q in (50, 95, 99)} \
            if ms.size else {}
        spec = self.spec.stats() if self.spec is not None else {}
        return SchedulerStats(
            kind=self.name, max_slots=self.max_slots,
            steps=self.steps_total, admitted=self.admitted,
            retired=self.retired, waves=self.waves,
            drains=self.drains, forced_swaps=self.forced_swaps,
            mean_occupancy=(self.occupancy_sum / self.steps_total
                            if self.steps_total else 0.0),
            max_occupancy=self.max_occupancy,
            prefill_chunk=self.chunk,
            chunk_steps=self.chunk_steps,
            pendings_started=self.pendings_started,
            pendings_abandoned=self.pendings_abandoned,
            step_ms=tail,
            kv=self.kv.stats(),
            speculative=self.spec is not None,
            **spec)

    # ------------------------------------------- chunked admission pipeline
    def _start_pending(self, queue, free_ids, fresh: bool,
                       version: int, limit_head: bool = False):
        """Pick requests for a chunked admission and commit its pad-to
        clock. Fresh waves reuse the contiguous pick (frozen clock: the
        wave's padding is the target); mid-flight picks grow the set under
        the solved target, re-checking every earlier choice as it rises.

        Paged backend: no clock to solve — ``kv.pick`` applies for fresh
        AND mid-flight picks alike (each entry's target is its own prompt
        length), and every entry's block budget is reserved up front."""
        if self.kv.backend == "paged":
            chosen, _ = self.kv.pick(queue, len(free_ids), fresh,
                                     limit_head)
            if not chosen:
                return []
            if fresh:
                self.waves += 1
            slot_ids = list(free_ids[:len(chosen)])
            for (_, r), slot in zip(chosen, slot_ids):
                self.kv.reserve_pending(slot, r)
            self._pending = PagedPendingPrefill(
                chosen=chosen, slot_ids=slot_ids, version=version)
            self.pendings_started += 1
            return chosen
        max_len = self.cfg.max_len
        if fresh:
            chosen, target = self.kv.pick(queue, len(free_ids), True, False)
        else:
            chosen = []
            target = None
            items = [queue[0]] if limit_head else list(queue)
            for item in items:
                if len(chosen) >= len(free_ids):
                    break
                _, r = item
                cand_t = self.kv.solve_target(
                    max([len(r.prompt)]
                        + [len(c.prompt) for _, c in chosen]))
                if cand_t is None:
                    continue
                if (cand_t + r.max_new_tokens <= max_len
                        and all(cand_t + c.max_new_tokens <= max_len
                                for _, c in chosen)):
                    chosen.append(item)
                    target = cand_t
            for item in chosen:
                queue.remove(item)
        if not chosen:
            return []
        if fresh:
            self.waves += 1
        k = len(chosen)
        tokens = np.full((k, target), self.cfg.pad_id, np.int32)
        for j, (_, r) in enumerate(chosen):
            tokens[j, target - len(r.prompt):] = np.asarray(r.prompt)
        self._pending = PendingPrefill(chosen=chosen,
                                       slot_ids=list(free_ids[:k]),
                                       target=target, version=version,
                                       tokens=tokens)
        self.pendings_started += 1
        return chosen

    def _advance_pending(self, params) -> float:
        """Consume up to ``prefill_chunk`` positions of the pending's
        padded prompt on the side cache; returns the chunk's wall time."""
        p = self._pending
        if isinstance(p, PagedPendingPrefill):
            return self._advance_pending_paged(params)
        n = min(self.chunk, p.remaining)
        if n <= 0:
            return 0.0
        if p.cache is None:
            p.cache = self.kv.side_cache(len(p.slot_ids))
        t0 = time.perf_counter()
        toks = jnp.asarray(p.tokens[:, p.done:p.done + n])
        # synchronous on purpose: letting chunks queue up async behind the
        # in-flight decode reads as overlap on idle machines but builds an
        # unbounded compute backlog on saturated ones, which the scatter
        # step then pays in one spike — the exact tail this feature bounds
        p.logits, p.cache = self.eng._prefill_chunk(
            params, {"tokens": toks}, p.cache)
        jax.block_until_ready(p.logits)
        ms = (time.perf_counter() - t0) * 1e3
        p.prefill_ms += ms
        p.chunks += 1
        p.done += n
        self.chunk_steps += 1
        return ms

    def _advance_pending_paged(self, params) -> float:
        """One chunk step of the current paged pending entry. The first
        step pins + gathers the entry's shared prefix (``begin_chunked_
        admit``); each step consumes up to ``prefill_chunk`` unshared
        suffix positions on the 1-row side cache (batch 1, unpadded — the
        monolithic admission shapes, so greedy tokens are bit-identical
        for any chunk split); a completed entry scatters into its reserved
        blocks and starts decoding immediately while later entries keep
        chunking. Returns the step's chunk wall time."""
        p = self._pending
        _, r = p.chosen[p.entry]
        slot = p.slot_ids[p.entry]
        t0 = time.perf_counter()
        if p.cache is None:
            p.lp, p.cache = self.kv.begin_chunked_admit(slot, r)
            p.suffix = np.asarray(
                [int(t) for t in r.prompt[p.lp:]], np.int32)
            p.done = 0
            p.chunks = 0
            p.entry_ms = 0.0
        n = min(self.chunk, len(p.suffix) - p.done)
        toks = jnp.asarray(p.suffix[None, p.done:p.done + n])
        # synchronous for the same tail-bounding reason as the contiguous
        # path: chunks must not queue up behind the in-flight decode
        p.logits, p.cache = self.eng._prefill_chunk(
            params, {"tokens": toks}, p.cache)
        jax.block_until_ready(p.logits)
        p.done += n
        p.chunks += 1
        self.chunk_steps += 1
        if p.done >= len(p.suffix):
            self.kv.complete_chunked_admit(slot, r, p.lp, p.cache,
                                           p.logits)
            ms = (time.perf_counter() - t0) * 1e3
            p.entry_ms += ms
            order = p.chosen[p.entry][0]
            self.slots[slot] = _Slot(
                order=order, req=r, version=p.version,
                clock0=len(r.prompt), t0=time.perf_counter(),
                prefill_ms=p.entry_ms, swap_ms=self._pending_swap_ms)
            if self.spec is not None:
                # the drafter needs its own prompt K/V before the slot's
                # first speculative cycle (one unpadded batch-1 prefill on
                # the draft tree — speculative composes with chunked
                # admission; only the verifier side is chunked)
                self.spec.admit_slot(slot, r.prompt,
                                     self._ver.draft_params)
            self.admission_log.append(
                {"request_id": r.request_id, "slot": slot,
                 "clock": len(r.prompt), "version": p.version,
                 "chunks": p.chunks})
            self.admitted += 1
            p.entry += 1
            p.cache = None
            if p.entry >= len(p.chosen):
                self._pending_swap_ms = 0.0
                self._pending = None
            return ms
        ms = (time.perf_counter() - t0) * 1e3
        p.entry_ms += ms
        return ms

    def _scatter_pending(self, p: PendingPrefill) -> None:
        """A completed pending joins the pool: scatter its side-cache rows
        and final-token logits (the ``admit_rows`` path inside the KV
        cache) and create its slots at the committed clock."""
        t0 = time.perf_counter()
        self.kv.scatter(p)
        p.prefill_ms += (time.perf_counter() - t0) * 1e3
        t_now = time.perf_counter()
        for j, (order, r) in enumerate(p.chosen):
            self.slots[p.slot_ids[j]] = _Slot(
                order=order, req=r, version=p.version, clock0=p.target,
                t0=t_now, prefill_ms=p.prefill_ms,
                swap_ms=self._pending_swap_ms)
            self.admission_log.append(
                {"request_id": r.request_id, "slot": p.slot_ids[j],
                 "clock": p.target, "version": p.version,
                 "chunks": p.chunks})
        self._pending_swap_ms = 0.0
        self.admitted += len(p.chosen)
        self._pending = None

    def _abandon_pending(self, queue) -> None:
        """A force-swap lands while a chunked admission is mid-prefill: its
        chunks ran on the outgoing weights, so drop the side cache and
        return its requests to the front of the queue in FCFS order (they
        re-admit under the new version).

        Paged backend: entries that already completed are live slots and
        drain/swap like any resident; the not-yet-complete entries must
        also release their reserved-block budgets and unpin their
        shared-prefix blocks (``abandon_chunked_admit``) — dropping only
        the side cache would leak both until pool exhaustion."""
        p = self._pending
        if isinstance(p, PagedPendingPrefill):
            for j in range(len(p.chosen) - 1, p.entry - 1, -1):
                self.kv.abandon_chunked_admit(p.slot_ids[j])
                queue.appendleft(p.chosen[j])
        else:
            for item in reversed(p.chosen):
                queue.appendleft(item)
        self._pending = None
        self.pendings_abandoned += 1

    def _sync_kv_version(self, version: int) -> None:
        """Cached prefix K/V blocks are weight-version-dependent: whenever
        the acquired version differs from the one the KV cache was built
        under, flush its reuse state before any admission runs on it."""
        if self._kv_version != version:
            if self._kv_version is not None:
                self.kv.on_weight_swap()
            self._kv_version = version

    def _admit(self, chosen, free_ids, clock, params, version: int):
        """Admit ``chosen`` into the first ``len(chosen)`` free slots via
        the KV cache (contiguous: one left-padded batch prefill + row
        scatter; paged: per-request prefix lookup + suffix prefill + block
        scatter, where ``clock`` is None and each slot's position is its
        own prompt length)."""
        k = len(chosen)
        slot_ids = list(free_ids[:k])
        t0 = time.perf_counter()
        self.kv.admit(chosen, slot_ids, clock, params)
        prefill_ms = (time.perf_counter() - t0) * 1e3
        t_now = time.perf_counter()
        for j, (order, r) in enumerate(chosen):
            c0 = clock if clock is not None else len(r.prompt)
            self.slots[slot_ids[j]] = _Slot(
                order=order, req=r, version=version, clock0=c0,
                t0=t_now, prefill_ms=prefill_ms,
                swap_ms=self._pending_swap_ms)
            if self.spec is not None:
                self.spec.admit_slot(slot_ids[j], r.prompt,
                                     self._ver.draft_params)
            self.admission_log.append(
                {"request_id": r.request_id, "slot": slot_ids[j],
                 "clock": c0, "version": version})
        self._pending_swap_ms = 0.0
        self.admitted += k
