"""Versioned quantized weight store for the serving engine.

SQuant's sub-second, data-free cost makes quantize-on-reload viable inside a
live serving loop: fresh fp weights can be quantized *while serving
continues* and swapped in between decode rounds. This module owns that
machinery so the engine never touches ``quantize_tree`` directly.

Model
-----
* ``WeightVersion`` — an immutable (version, params, report, provenance)
  snapshot. Versions increase monotonically per store.
* ``WeightStore`` — double-buffered: exactly one **live** version (what
  rounds currently read) and at most one **staged** version (fully built,
  device-resident, waiting to be swapped in). Staging happens on a
  background worker (latest request wins); the swap itself is a pointer
  flip a scheduler performs only at its swap points via
  :meth:`WeightStore.acquire` — round boundaries for the round scheduler,
  drained (or deadline-forced) step boundaries for the continuous one —
  so an in-flight round can never observe a torn tree: it holds the
  ``WeightVersion`` it started with. Schedulers watch
  :attr:`WeightStore.staged_pending` to begin draining and report the
  drain/swap through :meth:`note_drain`/:meth:`note_swap`.
* ``watch()`` — a poll thread over a checkpoint directory
  (``checkpoint.Checkpointer`` layout). New COMMITTED steps are restored
  (torn/corrupt step dirs are skipped), validated against the serve
  config's quant expectations, and staged: quantized checkpoints
  (``w_q``/``w_q4``/``w_scale`` serving trees) are served directly with no
  re-quantization; fp checkpoints go through the store's quantize_fn
  (the batched/sharded ``quantize_tree`` path).
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.quant.qtypes import QuantReport


@dataclasses.dataclass(frozen=True)
class WeightVersion:
    """One immutable generation of serving weights.

    ``draft_params`` is the self-speculative drafter tree (a lower-bit
    quantization of the SAME source the target ``params`` came from),
    staged and swapped atomically with the target so a reload can never
    pair a new verifier with an old drafter. None when the store has no
    draft pipeline (speculation off)."""
    version: int                       # monotonically increasing, from 1
    params: Any                        # serving tree (fp, fake-quant, qdict…)
    report: Optional[QuantReport] = None
    source: str = "init"               # "init" | "ckpt:<step>" | caller tag
    step: Optional[int] = None         # checkpoint step, when applicable
    staged_ms: float = 0.0             # quantize/prepare + device wall time
    draft_params: Any = None           # speculative drafter tree (or None)


def make_weight_pipeline(model, cfg):
    """``(model', quantize_fn, prepare_fn)`` for a ``ServeConfig``.

    ``model'`` is rebuilt with the layer stack unrolled for real-quantized
    serving (QuantizedTensor leaves cannot be scanned over — standard for
    serving anyway). ``quantize_fn`` maps an fp tree to
    ``(serving_tree, QuantReport | None)`` per the config (identity when
    ``cfg.quantize_weights`` is None). ``prepare_fn`` normalizes an
    *already-quantized* serving tree (a ``w_q``/``w_q4`` qdict restored from
    a checkpoint) for ``model'`` — identity unless the stack was unrolled.
    """
    from repro.core.pipeline import quantize_tree
    from repro.models.model import build_model
    from repro.models.transformer import n_periods, unstack_stack

    base_cfg = model.cfg
    unroll = bool(cfg.quantize_weights) and not cfg.dequantize_for_compute
    if unroll:
        model = build_model(dataclasses.replace(base_cfg, scan_layers=False))

    def _unstack(tree):
        if isinstance(tree, dict) and "periods" in tree.get("stack", {}):
            tree = dict(tree)
            tree["stack"] = unstack_stack(tree["stack"], n_periods(base_cfg))
        return tree

    def quantize_fn(fp_tree):
        if not cfg.quantize_weights:
            return fp_tree, None
        if unroll:
            fp_tree = _unstack(fp_tree)
        return quantize_tree(fp_tree, method=cfg.quantize_weights,
                             bits=cfg.weight_bits,
                             dequantize=cfg.dequantize_for_compute)

    return model, quantize_fn, (_unstack if unroll else (lambda t: t))


def make_draft_quantize_fn(model, cfg):
    """``fp tree -> draft serving tree`` for self-speculative serving.

    The drafter is the same checkpoint quantized at ``cfg.draft_bits``
    (data-free, sub-second — SQuant makes draft models free), prepared
    for the SAME model the target pipeline serves: the unroll decision
    mirrors :func:`make_weight_pipeline` so both trees match the (possibly
    scan-unrolled) serving stack. When the target serves fp
    (``quantize_weights`` None) the drafter still quantizes — the ladder
    needs a cheaper tree below the verifier — defaulting to 'squant'.
    """
    from repro.core.pipeline import quantize_tree
    from repro.models.transformer import n_periods, unstack_stack

    base_cfg = model.cfg
    unroll = bool(cfg.quantize_weights) and not cfg.dequantize_for_compute
    method = cfg.quantize_weights or "squant"

    def draft_fn(fp_tree):
        if unroll and isinstance(fp_tree, dict) \
                and "periods" in fp_tree.get("stack", {}):
            fp_tree = dict(fp_tree)
            fp_tree["stack"] = unstack_stack(fp_tree["stack"],
                                             n_periods(base_cfg))
        tree, _ = quantize_tree(fp_tree, method=method, bits=cfg.draft_bits,
                                dequantize=cfg.dequantize_for_compute)
        return tree

    return draft_fn


class WeightStore:
    """Double-buffered, versioned owner of serving weights.

    Exactly one of ``fp_params`` / ``serving_params`` seeds version 1:
    ``fp_params`` goes through ``quantize_fn``; ``serving_params`` is an
    already-serving-format tree (through ``prepare_fn``).
    """

    def __init__(self, quantize_fn: Optional[Callable] = None,
                 fp_params: Any = None, *, serving_params: Any = None,
                 prepare_fn: Optional[Callable] = None,
                 draft_quantize_fn: Optional[Callable] = None,
                 report: Optional[QuantReport] = None, source: str = "init"):
        if (fp_params is None) == (serving_params is None):
            raise ValueError("provide exactly one of fp_params or "
                             "serving_params")
        self._quantize_fn = quantize_fn
        self._prepare_fn = prepare_fn or (lambda t: t)
        # speculative serving: fp tree -> drafter tree, built alongside the
        # target in _build_and_publish so every version is a (target,
        # draft) pair. Requires fp sources: a quantized-native checkpoint
        # reload cannot rebuild the drafter, so such stages fail into
        # ``errors`` and serving continues on the previous pair.
        self._draft_quantize_fn = draft_quantize_fn
        self._lock = threading.Lock()
        self._counter = 0
        self._live: Optional[WeightVersion] = None
        self._staged: Optional[WeightVersion] = None
        self._queue: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._watch_stop: Optional[threading.Event] = None
        self._watch_thread: Optional[threading.Thread] = None
        self._last_ckpt_step = -1
        self._ckpt_retries = 0            # transient-failure retries per step
        self._staged_at = 0.0             # monotonic time of last staging
        self.swap_count = 0
        # reload-aware scheduler observability (note_drain/note_swap)
        self.drain_count = 0
        self.forced_swap_count = 0
        self.last_drain_ms = 0.0
        self.last_drain_in_flight = 0
        # bounded: a persistently failing watcher (e.g. deleted ckpt dir)
        # appends per poll and must not grow a long-lived server's memory
        self.errors: collections.deque = collections.deque(maxlen=256)
        self._build_and_publish(fp_params, serving_params, report, source,
                                None)
        with self._lock:
            self._live, self._staged = self._staged, None

    # ------------------------------------------------------------- accessors
    @property
    def current(self) -> WeightVersion:
        """The live version (no swap — see :meth:`acquire`)."""
        with self._lock:
            return self._live

    @property
    def version(self) -> int:
        return self.current.version

    @property
    def staged_pending(self) -> bool:
        """True when a fully-built version is waiting to be swapped in —
        the reload-aware scheduler's drain trigger (peek; no swap)."""
        with self._lock:
            return self._staged is not None

    def staged_info(self) -> Optional["StagedInfo"]:
        """:class:`repro.serving.api.StagedInfo` for the staged version,
        or None. ``age_ms`` is how long the version has been waiting —
        schedulers compare it against their swap deadline. (Supports
        ``["key"]`` access for pre-api.py dict-style consumers.)"""
        from repro.serving.api import StagedInfo
        with self._lock:
            if self._staged is None:
                return None
            return StagedInfo(
                version=self._staged.version,
                age_ms=(time.monotonic() - self._staged_at) * 1e3)

    # ------------------------------------------------- scheduler drain hooks
    def note_drain(self, in_flight: int = 0) -> None:
        """A scheduler observed the staged version and began draining
        (stopped admitting) with ``in_flight`` slots still decoding."""
        with self._lock:
            self.drain_count += 1
            self.last_drain_in_flight = in_flight

    def note_swap(self, forced: bool = False, drain_ms: float = 0.0) -> None:
        """A scheduler swapped after draining for ``drain_ms``; ``forced``
        means the swap-deadline expired with slots still in flight."""
        with self._lock:
            if forced:
                self.forced_swap_count += 1
            self.last_drain_ms = drain_ms

    def acquire(self) -> Tuple[WeightVersion, float]:
        """Swap in any fully-staged version and return ``(live, swap_ms)``.

        This is the ONLY place a new version becomes live. The engine calls
        it at decode-round boundaries; the returned snapshot stays valid for
        the whole round regardless of concurrent staging.
        """
        t0 = time.perf_counter()
        with self._lock:
            if self._staged is not None:
                self._live, self._staged = self._staged, None
                self.swap_count += 1
            live = self._live
        return live, (time.perf_counter() - t0) * 1e3

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            live, staged = self._live, self._staged
            return {"version": live.version, "source": live.source,
                    "step": live.step, "staged_ms": live.staged_ms,
                    "versions_built": self._counter,
                    "swaps": self.swap_count,
                    "drains": self.drain_count,
                    "forced_swaps": self.forced_swap_count,
                    "last_drain_ms": self.last_drain_ms,
                    "last_drain_in_flight": self.last_drain_in_flight,
                    "staged_pending": staged is not None,
                    "staged_version":
                        staged.version if staged is not None else None,
                    "watching": self._watch_thread is not None,
                    "errors": list(self.errors)}

    # --------------------------------------------------------------- staging
    def _build_and_publish(self, fp_params, serving_params, report, source,
                           step):
        t0 = time.perf_counter()
        if serving_params is not None:
            tree, rep = self._prepare_fn(serving_params), report
        else:
            if self._quantize_fn is None:
                raise ValueError("store has no quantize_fn; cannot stage "
                                 "fp params")
            tree, rep = self._quantize_fn(fp_params)
        draft = None
        if self._draft_quantize_fn is not None:
            if fp_params is None:
                # background stage() routes this into ``errors`` and keeps
                # serving the previous (target, draft) pair — a reload must
                # never drop the drafter out from under a speculating slot
                raise ValueError(
                    "speculative serving stages (target, draft) pairs from "
                    "one fp source; a quantized-native serving tree cannot "
                    "rebuild the drafter — reload fp checkpoints instead")
            draft = self._draft_quantize_fn(fp_params)
        # materialize now so the round-boundary swap is a pointer flip
        jax.block_until_ready(jax.tree_util.tree_leaves((tree, draft)))
        staged_ms = (time.perf_counter() - t0) * 1e3
        with self._lock:
            self._counter += 1
            self._staged = WeightVersion(self._counter, tree, rep, source,
                                         step, staged_ms, draft)
            self._staged_at = time.monotonic()

    def stage(self, fp_params: Any = None, *, serving_params: Any = None,
              report: Optional[QuantReport] = None, source: str = "manual",
              step: Optional[int] = None, block: bool = False):
        """Quantize/prepare a new weight tree and stage it for the next swap.

        ``block=False`` hands the work to the background worker (latest
        request wins if several arrive while one is building);
        ``block=True`` builds synchronously in the caller's thread.
        """
        if (fp_params is None) == (serving_params is None):
            raise ValueError("provide exactly one of fp_params or "
                             "serving_params")
        if block:
            self._build_and_publish(fp_params, serving_params, report,
                                    source, step)
            return
        self._queue.put((fp_params, serving_params, report, source, step))
        with self._lock:
            if self._worker is None or not self._worker.is_alive():
                self._worker = threading.Thread(target=self._stage_loop,
                                                daemon=True)
                self._worker.start()

    def _stage_loop(self):
        while True:
            req = self._queue.get()
            if req is None:
                return
            try:            # drain: only the newest pending request matters
                while True:
                    nxt = self._queue.get_nowait()
                    if nxt is None:
                        return
                    req = nxt
            except queue.Empty:
                pass
            try:
                self._build_and_publish(*req)
            except Exception as e:          # serving must outlive bad stages
                with self._lock:
                    self.errors.append(f"stage({req[3]}) failed: {e!r}")

    def wait_staged(self, version: Optional[int] = None,
                    timeout: float = 30.0) -> bool:
        """Block until a version newer than ``version`` (default: current
        live) has been built (staged or already swapped in)."""
        base = self.version if version is None else version
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                if self._counter > base:
                    return True
            time.sleep(0.005)
        return False

    # ------------------------------------------------------ checkpoint watch
    def poll_checkpoints(self, checkpointer, expect: Optional[dict] = None,
                         mesh=None) -> Optional[int]:
        """One watcher step: stage the newest unseen COMMITTED checkpoint.

        Torn step dirs (no COMMITTED) and corrupt ``index.json`` are
        invisible via ``list_steps``. Failures are recorded in ``errors``;
        metadata mismatches (permanent) are never retried, transient
        restore/stage failures are retried on the next few polls before the
        step is given up on. Returns the staged step, or None.
        """
        from repro.checkpoint.checkpointer import CheckpointMetaError

        steps = checkpointer.list_steps()
        if not steps or steps[-1] < self._last_ckpt_step or (
                steps[-1] == self._last_ckpt_step and
                self._ckpt_retries == 0):
            return None
        step = steps[-1]
        if step > self._last_ckpt_step:
            self._last_ckpt_step, self._ckpt_retries = step, 3
        try:
            tree, meta, _ = checkpointer.restore_serving(
                step, expect=expect, mesh=mesh)
            src = f"ckpt:{step}"
            if meta.get("format") == "quantized":
                self.stage(serving_params=tree, source=src, step=step,
                           block=True)
            else:
                self.stage(fp_params=tree, source=src, step=step,
                           block=True)
        except CheckpointMetaError as e:
            self._ckpt_retries = 0       # permanent: wrong bits/method
            with self._lock:
                self.errors.append(f"reload step {step} rejected: {e}")
            return None
        except Exception as e:
            self._ckpt_retries -= 1      # transient? retry a few polls
            with self._lock:
                self.errors.append(f"reload step {step} failed "
                                   f"({self._ckpt_retries} retries left): "
                                   f"{e!r}")
            return None
        self._ckpt_retries = 0
        return step

    def watch(self, ckpt_dir, poll_s: float = 1.0,
              expect: Optional[dict] = None, mesh=None):
        """Poll ``ckpt_dir`` in a daemon thread and stage new steps."""
        from repro.checkpoint.checkpointer import Checkpointer
        ck = Checkpointer(ckpt_dir, async_save=False) \
            if isinstance(ckpt_dir, str) else ckpt_dir
        if self._watch_thread is not None:
            raise RuntimeError("already watching a checkpoint directory")
        self._watch_stop = threading.Event()

        def loop():
            while not self._watch_stop.wait(poll_s):
                try:
                    self.poll_checkpoints(ck, expect=expect, mesh=mesh)
                except Exception as e:
                    with self._lock:
                        self.errors.append(f"watcher: {e!r}")

        self._watch_thread = threading.Thread(target=loop, daemon=True)
        self._watch_thread.start()

    def close(self):
        """Stop the watcher and the staging worker (idempotent)."""
        if self._watch_stop is not None:
            self._watch_stop.set()
            self._watch_thread.join(timeout=5)
            self._watch_thread, self._watch_stop = None, None
        if self._worker is not None and self._worker.is_alive():
            self._queue.put(None)
            self._worker.join(timeout=5)
        self._worker = None
