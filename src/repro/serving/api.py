"""Public serving API: request/response types and typed stats.

This is the deliberate public surface of :mod:`repro.serving` — promoted
out of ``serving/scheduler.py`` when self-speculative decoding forced the
serving loop to grow multi-token-per-step semantics. Import from here (or
from ``repro.serving``); ``repro.serving.scheduler.Request`` and
``repro.serving.engine.Request`` remain as deprecated aliases.

Types
-----
* :class:`Request` — one generation request. ``request_id`` is
  auto-assigned (process-unique) when left unset, and ``eos_id`` can
  override the engine-global ``ServeConfig.eos_id`` per request.
* :class:`Completion` — one finished request, with per-phase timings, the
  pinned weight version, and the speculative-decoding counters
  (``draft_tokens_proposed``/``draft_tokens_accepted`` are 0 when
  speculation is off; ``steps`` counts the engine sampling steps the
  request lived through — < ``len(tokens)`` when drafts were accepted).
* :class:`StagedInfo` — the staged weight version a reload-aware
  scheduler compares against its swap deadline.
* :class:`SchedulerStats` — ``scheduler.stats()`` as a typed record
  instead of an ad-hoc dict.

``StagedInfo`` and ``SchedulerStats`` support ``info["key"]`` /
``info.get("key")`` alongside attribute access so existing dict-style
consumers keep working across the API move.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Sequence

__all__ = ["Request", "Completion", "StagedInfo", "SchedulerStats"]

# process-unique auto ids for requests constructed without one; starts
# high so explicit small ids (the common test/example pattern) never clash
_AUTO_REQUEST_IDS = itertools.count(1 << 20)


class _ItemAccess:
    """Dict-style read access for dataclass stats records (migration
    shim: the pre-api.py ``stats()``/``staged_info()`` returned dicts)."""

    def __getitem__(self, key: str) -> Any:
        try:
            return getattr(self, key)
        except AttributeError:
            raise KeyError(key) from None

    def get(self, key: str, default: Any = None) -> Any:
        return getattr(self, key, default)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Request:
    """One generation request.

    ``request_id`` left at the default (None) is auto-assigned a
    process-unique id, so callers that don't need to correlate
    completions can omit it. ``eos_id`` overrides the engine-global
    ``ServeConfig.eos_id`` for this request only (None: use the
    engine's; -1: never stop early regardless of the engine's).
    """
    prompt: Sequence[int]
    max_new_tokens: int = 16
    request_id: Optional[int] = None
    eos_id: Optional[int] = None

    def __post_init__(self):
        if self.request_id is None:
            self.request_id = next(_AUTO_REQUEST_IDS)


@dataclasses.dataclass
class Completion:
    request_id: int
    tokens: List[int]
    prefill_ms: float
    decode_ms: float
    swap_ms: float = 0.0          # weight-swap time observed by this request
    weights_version: int = 1      # WeightStore version pinned at admission
    forced_swaps: int = 0         # deadline force-swaps that landed in flight
    steps: int = 0                # engine sampling steps this request spanned
    draft_tokens_proposed: int = 0   # speculative: drafts the w4 tree offered
    draft_tokens_accepted: int = 0   # speculative: drafts the verifier kept


@dataclasses.dataclass
class StagedInfo(_ItemAccess):
    """A fully-built weight version waiting to be swapped in; ``age_ms``
    is how long it has been waiting (schedulers compare it against their
    swap deadline)."""
    version: int
    age_ms: float


@dataclasses.dataclass
class SchedulerStats(_ItemAccess):
    """Typed ``scheduler.stats()`` record (both schedulers).

    Round fills only ``kind``/``steps``/``rounds``; the continuous
    scheduler fills the pool/admission/drain counters, the step-time
    tails, and — when speculative decoding is on — the acceptance
    telemetry: ``acceptance_rate`` is accepted/proposed draft tokens and
    ``accepted_len`` holds p50/p95 of per-slot tokens committed per
    verify cycle (1.0 == verifier-only pace).
    """
    kind: str
    steps: int = 0
    rounds: int = 0
    max_slots: int = 0
    admitted: int = 0
    retired: int = 0
    waves: int = 0
    drains: int = 0
    forced_swaps: int = 0
    mean_occupancy: float = 0.0
    max_occupancy: int = 0
    prefill_chunk: int = 0
    chunk_steps: int = 0
    pendings_started: int = 0
    pendings_abandoned: int = 0
    step_ms: Dict[str, float] = dataclasses.field(default_factory=dict)
    kv: Dict[str, Any] = dataclasses.field(default_factory=dict)
    speculative: bool = False
    spec_cycles: int = 0
    draft_tokens_proposed: int = 0
    draft_tokens_accepted: int = 0
    acceptance_rate: float = 0.0
    accepted_len: Dict[str, float] = dataclasses.field(default_factory=dict)
