"""Public serving API: request/response types and typed stats.

This is the deliberate public surface of :mod:`repro.serving` — promoted
out of ``serving/scheduler.py`` when self-speculative decoding forced the
serving loop to grow multi-token-per-step semantics. Import from here (or
from ``repro.serving``); ``repro.serving.scheduler.Request`` and
``repro.serving.engine.Request`` remain as deprecated aliases.

Types
-----
* :class:`Request` — one generation request. ``request_id`` is
  auto-assigned (process-unique) when left unset, and ``eos_id`` can
  override the engine-global ``ServeConfig.eos_id`` per request.
* :class:`Completion` — one finished request, with per-phase timings, the
  pinned weight version, and the speculative-decoding counters.
* :class:`StagedInfo` — the staged weight version a reload-aware
  scheduler compares against its swap deadline.
* :class:`SchedulerStats` — ``scheduler.stats()`` as a typed record
  instead of an ad-hoc dict.

``StagedInfo`` and ``SchedulerStats`` support ``info["key"]`` /
``info.get("key")`` alongside attribute access so existing dict-style
consumers keep working across the API move.

Example (doctest-checked in CI via ``python -m doctest``):

>>> from repro.serving.api import Request, Completion, SchedulerStats
>>> r = Request(prompt=[1, 2, 3], max_new_tokens=4, request_id=7)
>>> (r.request_id, r.eos_id)           # eos_id None: engine default
(7, None)
>>> auto = Request(prompt=[5])
>>> auto.request_id >= 1 << 20         # auto ids never clash with small
True
>>> c = Completion(request_id=7, tokens=[9, 9, 0], prefill_ms=1.5,
...                decode_ms=6.0)
>>> (c.weights_version, c.draft_tokens_accepted)
(1, 0)
>>> st = SchedulerStats(kind="continuous", steps=12, max_slots=4)
>>> st["steps"] == st.steps == 12      # dict-style shim still works
True
>>> st.get("missing", 0)
0
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Sequence

__all__ = ["Request", "Completion", "StagedInfo", "SchedulerStats"]

# process-unique auto ids for requests constructed without one; starts
# high so explicit small ids (the common test/example pattern) never clash
_AUTO_REQUEST_IDS = itertools.count(1 << 20)


class _ItemAccess:
    """Dict-style read access for dataclass stats records (migration
    shim: the pre-api.py ``stats()``/``staged_info()`` returned dicts)."""

    def __getitem__(self, key: str) -> Any:
        try:
            return getattr(self, key)
        except AttributeError:
            raise KeyError(key) from None

    def get(self, key: str, default: Any = None) -> Any:
        return getattr(self, key, default)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Request:
    """One generation request.

    Fields
    ------
    prompt
        Token ids to prefill (ints in ``[0, vocab)``); must be non-empty.
    max_new_tokens
        Exact number of tokens to generate unless ``eos_id`` stops the
        request early; the scheduler reserves cache space for all of them
        at admission.
    request_id
        Correlates the :class:`Completion`. Left at the default (None) it
        is auto-assigned a process-unique id (≥ ``1 << 20``, so explicit
        small ids never clash), for callers that don't need to correlate.
    eos_id
        Per-request end-of-sequence override. None: use the
        engine-global ``ServeConfig.eos_id``; -1: never stop early
        regardless of the engine's.
    """
    prompt: Sequence[int]
    max_new_tokens: int = 16
    request_id: Optional[int] = None
    eos_id: Optional[int] = None

    def __post_init__(self):
        if self.request_id is None:
            self.request_id = next(_AUTO_REQUEST_IDS)


@dataclasses.dataclass
class Completion:
    """One finished request.

    Fields
    ------
    request_id
        Echoes :attr:`Request.request_id`.
    tokens
        Generated token ids, in order — ``len(tokens) <
        max_new_tokens`` only when EOS stopped the request early.
    prefill_ms
        Wall-clock milliseconds spent prefilling this request's prompt
        (all chunks, for a chunked admission).
    decode_ms
        Wall-clock milliseconds from admission to retirement spent in
        decode/verify steps (shared steps are attributed to every
        resident request, not divided among them).
    swap_ms
        Milliseconds of weight-swap stall observed while this request
        was in flight (0.0 when no reload landed).
    weights_version
        ``WeightStore`` version pinned at admission — every token of
        this completion was produced by this version unless
        ``forced_swaps`` is non-zero.
    forced_swaps
        Number of deadline force-swaps that landed while in flight
        (> 0 means later tokens came from a newer weight version).
    steps
        Engine sampling steps the request lived through; with
        speculative decoding this is < ``len(tokens)`` when drafts were
        accepted (each accepted draft token skips a step).
    draft_tokens_proposed
        Speculative decoding only: draft tokens the low-bit tree
        proposed for this request's slot (0 when speculation is off).
    draft_tokens_accepted
        Speculative decoding only: proposed tokens the verifier kept
        (``accepted / proposed`` is this request's acceptance rate).
    """
    request_id: int
    tokens: List[int]
    prefill_ms: float
    decode_ms: float
    swap_ms: float = 0.0
    weights_version: int = 1
    forced_swaps: int = 0
    steps: int = 0
    draft_tokens_proposed: int = 0
    draft_tokens_accepted: int = 0


@dataclasses.dataclass
class StagedInfo(_ItemAccess):
    """A fully-built weight version waiting to be swapped in.

    Fields
    ------
    version
        The ``WeightStore`` version number that will become live at the
        next swap point.
    age_ms
        Milliseconds since the version finished staging — reload-aware
        schedulers compare this against ``swap_deadline_ms`` to decide
        between draining and force-swapping.
    """
    version: int
    age_ms: float


@dataclasses.dataclass
class SchedulerStats(_ItemAccess):
    """Typed ``scheduler.stats()`` record (both schedulers).

    The round scheduler fills only ``kind``/``steps``/``rounds``; the
    continuous scheduler fills everything else. Counters are cumulative
    over the scheduler's lifetime unless noted.

    Fields
    ------
    kind
        ``"round"`` or ``"continuous"``.
    steps
        Engine steps executed (decode or verify dispatches; a step
        serves every resident slot at once).
    rounds
        Round scheduler only: FCFS rounds completed.
    max_slots
        Decode-slot pool size (continuous).
    admitted / retired
        Requests admitted into / retired from the slot pool.
    waves
        Clock-horizon wave resets (the contiguous pool emptying and
        restarting its shared clock at 0).
    drains
        Reload drains entered (admission paused until in-flight slots
        retire or the swap deadline forces).
    forced_swaps
        Deadline force-swaps performed.
    mean_occupancy / max_occupancy
        Resident slots per step — time-averaged mean and peak
        (``mean_occupancy / max_slots`` is pool utilization).
    prefill_chunk
        Configured chunk width in prompt positions (0: monolithic).
    chunk_steps
        Engine steps that carried a chunk-prefill forward.
    pendings_started / pendings_abandoned
        Chunked admissions begun / abandoned by a force-swap (abandoned
        ones re-queue and restart on the new weights).
    step_ms
        Decode step-time tail percentiles in milliseconds:
        ``{"p50": ..., "p95": ..., "p99": ...}``.
    kv
        KV-backend stats passthrough (pool bytes, block counts, prefix
        hit rate — keys depend on the backend).
    speculative
        True when self-speculative decoding is on; the remaining fields
        are its telemetry (zero otherwise).
    spec_cycles
        Draft-verify cycles executed.
    draft_tokens_proposed / draft_tokens_accepted
        Draft tokens offered by the low-bit tree / kept by the
        verifier, summed over all slots.
    acceptance_rate
        ``draft_tokens_accepted / draft_tokens_proposed``.
    accepted_len
        Per-verify-cycle committed tokens per slot, percentiles
        ``{"p50": ..., "p95": ...}`` (1.0 == verifier-only pace).
    """
    kind: str
    steps: int = 0
    rounds: int = 0
    max_slots: int = 0
    admitted: int = 0
    retired: int = 0
    waves: int = 0
    drains: int = 0
    forced_swaps: int = 0
    mean_occupancy: float = 0.0
    max_occupancy: int = 0
    prefill_chunk: int = 0
    chunk_steps: int = 0
    pendings_started: int = 0
    pendings_abandoned: int = 0
    step_ms: Dict[str, float] = dataclasses.field(default_factory=dict)
    kv: Dict[str, Any] = dataclasses.field(default_factory=dict)
    speculative: bool = False
    spec_cycles: int = 0
    draft_tokens_proposed: int = 0
    draft_tokens_accepted: int = 0
    acceptance_rate: float = 0.0
    accepted_len: Dict[str, float] = dataclasses.field(default_factory=dict)
