"""Batched serving engine.

The request path SQuant enables: load fp weights → on-the-fly data-free
quantization (sub-second, no data, no BP — the paper's "on-the-fly
framework") → serve int8/int4 weights with dequant-on-the-fly matmuls and
optionally int8 KV caches.

Batching model: static continuous batch of ``max_batch`` slots. Requests are
left-padded to a common prefill length per micro-round (simple and fully
jittable); decode proceeds in lockstep with per-slot completion masks. Slots
are refilled between rounds (tests exercise multi-round refills).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import quantize_tree
from repro.serving.sampling import sample


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 256
    quantize_weights: Optional[str] = None    # None|'rtn'|'squant'|...
    weight_bits: int = 8
    quantize_kv: bool = False
    temperature: float = 0.0
    top_k: int = 0
    eos_id: int = -1                          # -1: never stop early
    pad_id: int = 0
    dequantize_for_compute: bool = True       # fake-quant serve on CPU


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new_tokens: int = 16
    request_id: int = 0


@dataclasses.dataclass
class Completion:
    request_id: int
    tokens: List[int]
    prefill_ms: float
    decode_ms: float


class ServeEngine:
    def __init__(self, model, params, cfg: ServeConfig):
        self.cfg = cfg
        self.quant_report = None
        if cfg.quantize_weights and not cfg.dequantize_for_compute:
            # real-quantized serving: QuantizedTensor leaves can't be scanned
            # over — unroll the layer stack (standard for serving anyway).
            import dataclasses as _dc
            from repro.models.model import build_model
            from repro.models.transformer import n_periods, unstack_stack
            if "periods" in params.get("stack", {}):
                params = dict(params)
                params["stack"] = unstack_stack(params["stack"],
                                                n_periods(model.cfg))
            model = build_model(_dc.replace(model.cfg, scan_layers=False))
        self.model = model
        if cfg.quantize_weights:
            params, self.quant_report = quantize_tree(
                params, method=cfg.quantize_weights, bits=cfg.weight_bits,
                dequantize=cfg.dequantize_for_compute)
        self.params = params
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        self._key = jax.random.PRNGKey(0)

    # ------------------------------------------------------------------ api
    def generate(self, requests: Sequence[Request]) -> List[Completion]:
        out: List[Completion] = []
        reqs = list(requests)
        while reqs:
            round_reqs = reqs[:self.cfg.max_batch]
            reqs = reqs[self.cfg.max_batch:]
            out.extend(self._run_round(round_reqs))
        return out

    # ---------------------------------------------------------------- round
    def _run_round(self, reqs: List[Request]) -> List[Completion]:
        b = len(reqs)
        pad_b = self.cfg.max_batch
        plen = max(len(r.prompt) for r in reqs)
        tokens = np.full((pad_b, plen), self.cfg.pad_id, np.int32)
        for i, r in enumerate(reqs):
            tokens[i, plen - len(r.prompt):] = np.asarray(r.prompt)

        cache = self.model.init_cache(pad_b, self.cfg.max_len,
                                      quantize_kv=self.cfg.quantize_kv)
        batch = {"tokens": jnp.asarray(tokens)}
        if self.model.cfg.is_encdec:
            batch["enc_frames"] = jnp.zeros(
                (pad_b, max(1, plen // self.model.cfg.enc_ratio),
                 self.model.cfg.d_model), jnp.float32)
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch, cache)
        jax.block_until_ready(logits)
        prefill_ms = (time.perf_counter() - t0) * 1e3

        max_new = max(r.max_new_tokens for r in reqs)
        produced = np.full((pad_b, max_new), self.cfg.pad_id, np.int32)
        done = np.zeros(pad_b, bool)
        t0 = time.perf_counter()
        cur = None
        for t in range(max_new):
            self._key, sk = jax.random.split(self._key)
            nxt = sample(logits, sk, self.cfg.temperature, self.cfg.top_k)
            nxt_np = np.asarray(nxt)
            for i, r in enumerate(reqs):
                if not done[i] and t < r.max_new_tokens:
                    produced[i, t] = nxt_np[i]
                    if nxt_np[i] == self.cfg.eos_id:
                        done[i] = True
                else:
                    done[i] = done[i] or t >= r.max_new_tokens
            if all(done[i] for i in range(b)):
                break
            cur = nxt[:, None]
            logits, cache = self._decode(self.params, cur, cache)
        jax.block_until_ready(logits)
        decode_ms = (time.perf_counter() - t0) * 1e3

        outs = []
        for i, r in enumerate(reqs):
            toks = [int(x) for x in produced[i, :r.max_new_tokens]]
            # truncate at EOS
            if self.cfg.eos_id >= 0 and self.cfg.eos_id in toks:
                toks = toks[:toks.index(self.cfg.eos_id) + 1]
            outs.append(Completion(r.request_id, toks, prefill_ms,
                                   decode_ms))
        return outs
