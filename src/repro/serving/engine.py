"""Serving engine: a thin front over the slot schedulers.

The request path SQuant enables: load fp weights → on-the-fly data-free
quantization (sub-second, no data, no BP — the paper's "on-the-fly
framework") → serve int8/int4 weights with dequant-on-the-fly matmuls and
optionally int8 KV caches.

Scheduling lives in :mod:`repro.serving.scheduler`:

* ``scheduler="round"`` (default) — static rounds of up to ``max_batch``
  left-padded requests; every request in a round waits for the longest one,
  and weight swaps land only between rounds.
* ``scheduler="continuous"`` — a fixed pool of ``max_slots`` decode slots
  over one persistent KV cache: queued requests are admitted into free
  slots at step boundaries, retire on EOS/max-tokens immediately, and a
  staged weight reload drains admission and swaps at a step boundary
  (force-swap after ``swap_deadline_ms``). With ``prefill_chunk > 0`` an
  admission prefill is consumed chunk-by-chunk across engine steps while
  resident slots keep decoding, bounding per-step tail latency. On
  plain-attention dense stacks greedy tokens stay bit-identical to the
  monolithic path at equal padding; MLA / sliding-window / MoE /
  mamba / rwkv stacks chunk-continue their own mixer state and serve
  under measured per-architecture agreement budgets
  (:mod:`repro.serving.equivalence`, ``docs/equivalence.md``).

KV-cache layout is a separate axis (``kv_backend``, see
:mod:`repro.serving.kvcache`): ``"contiguous"`` keeps the one-cache-row-
per-slot layout; ``"paged"`` (continuous scheduler only) stores K/V in
fixed-size blocks behind per-slot block tables with refcounted shared-
prefix reuse and copy-on-write — repeated system prompts prefill once,
and admission is bounded by a block budget instead of the shared clock
horizon.

Weight ownership lives in :class:`repro.serving.weights.WeightStore`, not
the engine: schedulers *acquire* a weight version at their swap points and
pin it per round / per slot, so a concurrent reload can never tear an
in-flight request. ``Completion`` reports ``prefill_ms``/``decode_ms``/
``swap_ms``, the pinned ``weights_version``, and (continuous only) how many
deadline ``forced_swaps`` landed mid-flight.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax

from repro.serving.api import Completion, Request
from repro.serving.kvcache import admit_rows
from repro.serving.scheduler import ContinuousScheduler, RoundScheduler
from repro.serving.weights import (WeightStore, make_draft_quantize_fn,
                                   make_weight_pipeline)

__all__ = ["ServeConfig", "Request", "Completion", "ServeEngine",
           "CONFIG_GATES", "ConfigGate", "ARCH_GATES", "ArchGate"]


# ---------------------------------------------------------------------------
# declarative config validation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConfigGate:
    """One row of the ServeConfig validity matrix: ``invalid(cfg)`` true
    means the config is rejected with ``error(message)``. Feature-pair
    gates use the uniform ``"unsupported combination: ..."`` prefix;
    plain range/enum rows keep their direct messages. The table replaces
    the accreted ``__post_init__`` if-chain so a new feature lands as a
    row (and one parametrized test enumerates every row), not a branch."""
    name: str
    invalid: Callable[["ServeConfig"], bool]
    error: type
    message: Union[str, Callable[["ServeConfig"], str]]

    def check(self, cfg: "ServeConfig") -> None:
        if self.invalid(cfg):
            msg = self.message(cfg) if callable(self.message) \
                else self.message
            raise self.error(msg)


CONFIG_GATES: Tuple[ConfigGate, ...] = (
    # ---- range / enum rows -------------------------------------------------
    ConfigGate(
        "prefill_chunk_range",
        lambda c: c.prefill_chunk < 0, ValueError,
        "prefill_chunk must be >= 0"),
    ConfigGate(
        "kv_backend_enum",
        lambda c: c.kv_backend not in ("contiguous", "paged"), ValueError,
        lambda c: f"unknown kv_backend {c.kv_backend!r} "
                  "(expected 'contiguous' or 'paged')"),
    ConfigGate(
        "block_size_range",
        lambda c: c.kv_backend == "paged" and c.block_size < 1, ValueError,
        "block_size must be >= 1"),
    ConfigGate(
        "block_size_divides",
        lambda c: c.kv_backend == "paged" and c.block_size >= 1
        and c.max_len % c.block_size != 0, ValueError,
        lambda c: f"block_size ({c.block_size}) must divide max_len "
                  f"({c.max_len}): the per-slot block table must span "
                  "exactly max_len positions for bit-compatibility with "
                  "the contiguous backend"),
    ConfigGate(
        "kv_blocks_range",
        lambda c: c.kv_backend == "paged" and c.kv_blocks < 0, ValueError,
        "kv_blocks must be >= 0"),
    ConfigGate(
        "draft_k_range",
        lambda c: c.speculative and c.draft_k < 1, ValueError,
        "draft_k must be >= 1"),
    ConfigGate(
        "draft_bits_range",
        lambda c: c.speculative and not 2 <= c.draft_bits <= 8, ValueError,
        lambda c: f"draft_bits ({c.draft_bits}) must be in [2, 8]"),
    # ---- feature-pair rows (uniform "unsupported combination:" prefix) -----
    ConfigGate(
        "paged_x_round",
        lambda c: c.kv_backend == "paged" and c.scheduler != "continuous",
        NotImplementedError,
        "unsupported combination: kv_backend='paged' requires "
        "scheduler='continuous' (the round scheduler's per-round caches "
        "are contiguous by construction)"),
    ConfigGate(
        "speculative_x_contiguous",
        lambda c: c.speculative and c.kv_backend != "paged",
        NotImplementedError,
        "unsupported combination: speculative decoding requires "
        "kv_backend='paged' (the verifier rewinds per-slot positions on "
        "draft rejection; the contiguous/lockstep cache has one shared "
        "clock and cannot rewind a single slot)"),
    ConfigGate(
        "speculative_x_quant_kv",
        lambda c: c.speculative and c.quantize_kv,
        NotImplementedError,
        "unsupported combination: speculative x quantize_kv (greedy "
        "acceptance promises tokens bit-identical to verifier-only "
        "decode, which needs the fp KV pool; int8 KV is tolerance-"
        "equivalent only)"),
    ConfigGate(
        "speculative_x_sampling",
        lambda c: c.speculative and (c.temperature > 0 or c.top_k > 0),
        NotImplementedError,
        "unsupported combination: speculative x sampling "
        "(temperature/top_k): greedy acceptance compares argmax tokens; "
        "set temperature=0 and top_k=0"),
)


@dataclasses.dataclass(frozen=True)
class ArchGate:
    """One row of the (ServeConfig × architecture) validity matrix — the
    model-dependent sibling of :data:`CONFIG_GATES`. ``invalid(cfg,
    arch_cfg)`` true rejects the pairing with ``error(message)``. Checked
    once in :class:`ServeEngine.__init__` (the first point where both the
    serve config and the model are known), and enumerated — together with
    ``CONFIG_GATES`` and ``repro.serving.equivalence.AGREEMENT_BUDGETS`` —
    by ``scripts/gen_support_matrix.py`` to render
    ``docs/support-matrix.md``.

    Architecture gates are deliberately few: chunked prefill is NOT gated
    on architecture anymore — every decoder-only mixer has a
    chunk-continuation path and serves under its measured agreement budget
    (see :mod:`repro.serving.equivalence`). What remains gated is what has
    no implementation at all, not what is merely tolerance-equivalent."""
    name: str
    invalid: Callable[["ServeConfig", Any], bool]
    error: type
    message: str

    def check(self, cfg: "ServeConfig", arch_cfg: Any) -> None:
        if self.invalid(cfg, arch_cfg):
            raise self.error(self.message)


def _arch_features(arch_cfg) -> Tuple[str, ...]:
    from repro.models.model import arch_features
    return arch_features(arch_cfg)


ARCH_GATES: Tuple[ArchGate, ...] = (
    ArchGate(
        "encdec_x_continuous",
        lambda c, a: c.scheduler == "continuous" and a.is_encdec,
        NotImplementedError,
        "continuous scheduler does not support encoder-decoder models yet "
        "(per-slot encoder outputs have admission-dependent lengths); use "
        "scheduler='round'"),
    ArchGate(
        "paged_x_non_positional_kv",
        lambda c, a: c.kv_backend == "paged" and any(
            f in ("mla", "sliding_window", "mamba", "rwkv")
            for f in _arch_features(a)),
        NotImplementedError,
        "the paged KV cache requires per-position cache rows: MLA "
        "compressed-latent caches, sliding-window rings, and mamba/rwkv "
        "recurrent state cannot be block-paged; use "
        "kv_backend='contiguous' (MoE stacks with plain attention page "
        "fine — only the sequence-mixer cache layout matters)"),
)


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 256
    quantize_weights: Optional[str] = None    # None|'rtn'|'squant'|...
    weight_bits: int = 8
    quantize_kv: bool = False
    temperature: float = 0.0
    top_k: int = 0
    eos_id: int = -1                          # -1: never stop early
    pad_id: int = 0
    dequantize_for_compute: bool = True       # fake-quant serve on CPU
    scheduler: str = "round"                  # 'round' | 'continuous'
    max_slots: int = 0                        # slot-pool size (0: max_batch)
    # continuous only: max ms to drain in-flight slots before a staged
    # weight version is force-swapped at a step boundary (None: drain fully)
    swap_deadline_ms: Optional[float] = 250.0
    # continuous only: admission prefill consumes at most this many prompt
    # positions per engine step while resident slots keep decoding, bounding
    # the step-time spike a long-prompt admission causes (0: monolithic
    # prefill, the round scheduler always prefills monolithically).
    # Composes with kv_backend='paged': each pending entry chunk-prefills
    # its own unshared suffix at its own position (no shared clock), so any
    # chunk size works mid-flight and tokens stay bit-identical
    prefill_chunk: int = 0
    # continuous only: after this many mid-flight admissions that skipped
    # the queue head, admission narrows to the head until it lands (FCFS-
    # with-skip would otherwise starve a long request behind a stream of
    # short ones that keeps the pool from ever emptying)
    starvation_limit: int = 32
    # KV-cache backend (see repro.serving.kvcache): 'contiguous' is the
    # original one-cache-row-per-slot layout; 'paged' (continuous scheduler
    # only) stores K/V in fixed-size blocks behind per-slot block tables
    # with shared-prefix reuse and copy-on-write
    kv_backend: str = "contiguous"
    # paged only: positions per KV block; must divide max_len (the per-slot
    # table then spans exactly max_len positions, keeping paged decode
    # shape- and bit-compatible with the contiguous oracle)
    block_size: int = 16
    # paged only: physical blocks in the pool, including the reserved trash
    # block (0: full capacity, max_slots * (max_len // block_size) + 1 —
    # no admission backpressure; smaller pools admit under a block budget)
    kv_blocks: int = 0
    # self-speculative decoding (paged + continuous + greedy only): a
    # draft_bits quantization of the SAME checkpoint autoregressively
    # proposes draft_k-token runs per slot, the serving tree verifies all
    # positions in one batched multi-position forward, and the longest
    # matching prefix is accepted — output tokens stay bit-identical to
    # verifier-only decode (greedy acceptance), only the steps-per-token
    # changes. quantize_kv composes with prefill_chunk AND paged (the
    # former gates are gone; tokens are tolerance-equivalent under int8
    # KV), but NOT with speculative — see CONFIG_GATES.
    speculative: bool = False
    # speculative only: bit-width of the drafter quantized from the same
    # fp tree (the SQuant ladder: sub-second, data-free — drafts for free)
    draft_bits: int = 4
    # speculative only: draft tokens proposed per cycle; the verifier
    # scores all draft_k + 1 positions (carry token + proposals) in one
    # batched multi-position forward
    draft_k: int = 4

    def __post_init__(self):
        for gate in CONFIG_GATES:
            gate.check(self)


class ServeEngine:
    def __init__(self, model, params=None, cfg: ServeConfig = None, *,
                 store: Optional[WeightStore] = None):
        self.cfg = cfg or ServeConfig()
        # weight preparation (scan-unroll for real-quantized serving +
        # quantize_tree) lives in serving.weights; the engine only consumes
        # versioned serving trees.
        self.model, quantize_fn, prepare_fn = \
            make_weight_pipeline(model, self.cfg)
        # model-dependent feasibility (CONFIG_GATES ran in ServeConfig's
        # __post_init__; these rows need the architecture too)
        for gate in ARCH_GATES:
            gate.check(self.cfg, self.model.cfg)
        if store is None:
            if params is None:
                raise ValueError("ServeEngine needs params or a store")
            # speculative serving stages a versioned (target, draft) pair:
            # both trees quantized from the one fp source, swapped
            # atomically so a reload can never mix generations
            draft_fn = make_draft_quantize_fn(model, self.cfg) \
                if self.cfg.speculative else None
            store = WeightStore(quantize_fn, fp_params=params,
                                prepare_fn=prepare_fn,
                                draft_quantize_fn=draft_fn)
        self.store = store
        # jit entry points with trace accounting: each counter increments
        # only when jax traces a new shape specialization, so tests can
        # assert same-shape rounds/steps never retrace
        self.trace_counts: Dict[str, int] = \
            {"prefill": 0, "prefill_chunk": 0, "decode": 0, "admit": 0}
        self._prefill = self._jit_counted("prefill", self.model.prefill)
        # chunk continuation: one trace per distinct chunk length (the
        # start offset is a traced cache scalar, so it never retraces)
        self._prefill_chunk = self._jit_counted("prefill_chunk",
                                                self.model.prefill_chunk)
        self._decode = self._jit_counted("decode", self.model.decode_step)
        self._admit_rows = self._jit_counted("admit", admit_rows)
        self._key = jax.random.PRNGKey(0)
        self._rounds_total = 0
        # bounded: a watch-forever server must not grow per-round state
        self._round_log: collections.deque = collections.deque(maxlen=1024)
        # optional per-step instrumentation hook (tests/benches): called
        # with {"step", "recorded", "version", "draining", "t", ...} after
        # each lockstep sampling step
        self.on_step = None
        if self.cfg.scheduler == "continuous":
            self.scheduler = ContinuousScheduler(self)
        elif self.cfg.scheduler == "round":
            self.scheduler = RoundScheduler(self)
        else:
            raise ValueError(f"unknown scheduler {self.cfg.scheduler!r} "
                             "(expected 'round' or 'continuous')")

    def _jit_counted(self, name: str, fn):
        def counted(*args):
            self.trace_counts[name] += 1   # runs at trace time only
            return fn(*args)
        return jax.jit(counted)

    # ------------------------------------------------------------ weights
    @property
    def params(self):
        """The live serving tree (current weight version)."""
        return self.store.current.params

    @property
    def quant_report(self):
        return self.store.current.report

    def watch_checkpoints(self, ckpt_dir: str, poll_s: float = 1.0,
                          mesh=None):
        """Hot-reload: poll ``ckpt_dir`` for new COMMITTED steps and stage
        them (quantizing fp trees on the fly, loading quantized trees
        natively); swaps land at the scheduler's next swap point (round
        boundary, or continuous drain/deadline)."""
        self.store.watch(ckpt_dir, poll_s=poll_s, mesh=mesh,
                         expect={"quantize_weights": self.cfg.quantize_weights,
                                 "weight_bits": self.cfg.weight_bits})

    def stats(self) -> Dict[str, Any]:
        """Engine + scheduler + weight-store observability: per-round
        timing log (round scheduler; last 1024 rounds), scheduler counters
        (steps/admissions/drains/forced swaps), jit trace counts, and
        swap/version counters."""
        return {"rounds": self._rounds_total,
                "round_log": list(self._round_log),
                "scheduler": self.scheduler.stats(),
                "trace_counts": dict(self.trace_counts),
                "weights": self.store.stats()}

    def close(self):
        self.store.close()

    # ------------------------------------------------------------------ api
    def generate(self, requests: Sequence[Request]) -> List[Completion]:
        return self.scheduler.run(list(requests))
