"""Batched serving engine.

The request path SQuant enables: load fp weights → on-the-fly data-free
quantization (sub-second, no data, no BP — the paper's "on-the-fly
framework") → serve int8/int4 weights with dequant-on-the-fly matmuls and
optionally int8 KV caches.

Batching model: static continuous batch of ``max_batch`` slots. Requests are
left-padded to a common prefill length per micro-round (simple and fully
jittable); decode proceeds in lockstep with per-slot completion masks. Slots
are refilled between rounds (tests exercise multi-round refills).

Weight ownership lives in :class:`repro.serving.weights.WeightStore`, not
the engine: each round starts by *acquiring* a weight version — the only
point where a staged version can swap in — and holds that snapshot for the
whole round, so a concurrent reload can never tear an in-flight request.
``Completion`` reports per-round ``prefill_ms``/``decode_ms``/``swap_ms``
and the serving ``weights_version`` so reload stalls are observable.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampling import sample
from repro.serving.weights import WeightStore, make_weight_pipeline


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 256
    quantize_weights: Optional[str] = None    # None|'rtn'|'squant'|...
    weight_bits: int = 8
    quantize_kv: bool = False
    temperature: float = 0.0
    top_k: int = 0
    eos_id: int = -1                          # -1: never stop early
    pad_id: int = 0
    dequantize_for_compute: bool = True       # fake-quant serve on CPU


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new_tokens: int = 16
    request_id: int = 0


@dataclasses.dataclass
class Completion:
    request_id: int
    tokens: List[int]
    prefill_ms: float
    decode_ms: float
    swap_ms: float = 0.0          # round-boundary weight-swap time
    weights_version: int = 1      # WeightStore version the round served


class ServeEngine:
    def __init__(self, model, params=None, cfg: ServeConfig = None, *,
                 store: Optional[WeightStore] = None):
        self.cfg = cfg or ServeConfig()
        # weight preparation (scan-unroll for real-quantized serving +
        # quantize_tree) lives in serving.weights; the engine only consumes
        # versioned serving trees.
        self.model, quantize_fn, prepare_fn = \
            make_weight_pipeline(model, self.cfg)
        if store is None:
            if params is None:
                raise ValueError("ServeEngine needs params or a store")
            store = WeightStore(quantize_fn, fp_params=params,
                                prepare_fn=prepare_fn)
        self.store = store
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)
        self._key = jax.random.PRNGKey(0)
        self._rounds_total = 0
        # bounded: a watch-forever server must not grow per-round state
        self._round_log: collections.deque = collections.deque(maxlen=1024)

    # ------------------------------------------------------------ weights
    @property
    def params(self):
        """The live serving tree (current weight version)."""
        return self.store.current.params

    @property
    def quant_report(self):
        return self.store.current.report

    def watch_checkpoints(self, ckpt_dir: str, poll_s: float = 1.0,
                          mesh=None):
        """Hot-reload: poll ``ckpt_dir`` for new COMMITTED steps and stage
        them (quantizing fp trees on the fly, loading quantized trees
        natively); swaps land at the next decode-round boundary."""
        self.store.watch(ckpt_dir, poll_s=poll_s, mesh=mesh,
                         expect={"quantize_weights": self.cfg.quantize_weights,
                                 "weight_bits": self.cfg.weight_bits})

    def stats(self) -> Dict[str, Any]:
        """Engine + weight-store observability: per-round timing log
        (prefill/decode/swap ms and served version; last 1024 rounds) and
        swap/version counters."""
        return {"rounds": self._rounds_total,
                "round_log": list(self._round_log),
                "weights": self.store.stats()}

    def close(self):
        self.store.close()

    # ------------------------------------------------------------------ api
    def generate(self, requests: Sequence[Request]) -> List[Completion]:
        out: List[Completion] = []
        reqs = list(requests)
        while reqs:
            round_reqs = reqs[:self.cfg.max_batch]
            reqs = reqs[self.cfg.max_batch:]
            out.extend(self._run_round(round_reqs))
        return out

    # ---------------------------------------------------------------- round
    def _run_round(self, reqs: List[Request]) -> List[Completion]:
        # the ONLY swap point: in-flight rounds hold `ver` to the end
        ver, swap_ms = self.store.acquire()
        params = ver.params
        b = len(reqs)
        pad_b = self.cfg.max_batch
        plen = max(len(r.prompt) for r in reqs)
        tokens = np.full((pad_b, plen), self.cfg.pad_id, np.int32)
        for i, r in enumerate(reqs):
            tokens[i, plen - len(r.prompt):] = np.asarray(r.prompt)

        cache = self.model.init_cache(pad_b, self.cfg.max_len,
                                      quantize_kv=self.cfg.quantize_kv)
        batch = {"tokens": jnp.asarray(tokens)}
        if self.model.cfg.is_encdec:
            batch["enc_frames"] = jnp.zeros(
                (pad_b, max(1, plen // self.model.cfg.enc_ratio),
                 self.model.cfg.d_model), jnp.float32)
        t0 = time.perf_counter()
        logits, cache = self._prefill(params, batch, cache)
        jax.block_until_ready(logits)
        prefill_ms = (time.perf_counter() - t0) * 1e3

        max_new = max(r.max_new_tokens for r in reqs)
        produced = np.full((pad_b, max_new), self.cfg.pad_id, np.int32)
        done = np.zeros(pad_b, bool)
        t0 = time.perf_counter()
        cur = None
        for t in range(max_new):
            self._key, sk = jax.random.split(self._key)
            nxt = sample(logits, sk, self.cfg.temperature, self.cfg.top_k)
            nxt_np = np.asarray(nxt)
            for i, r in enumerate(reqs):
                if not done[i] and t < r.max_new_tokens:
                    produced[i, t] = nxt_np[i]
                    if nxt_np[i] == self.cfg.eos_id:
                        done[i] = True
                else:
                    done[i] = done[i] or t >= r.max_new_tokens
            if all(done[i] for i in range(b)):
                break
            cur = nxt[:, None]
            logits, cache = self._decode(params, cur, cache)
        jax.block_until_ready(logits)
        decode_ms = (time.perf_counter() - t0) * 1e3

        # the round ran start-to-finish on `ver`; a version staged mid-round
        # becomes visible only to the next acquire() (asserted in tests)
        self._rounds_total += 1
        self._round_log.append({"version": ver.version,
                                "prefill_ms": prefill_ms,
                                "decode_ms": decode_ms,
                                "swap_ms": swap_ms,
                                "requests": b})

        outs = []
        for i, r in enumerate(reqs):
            toks = [int(x) for x in produced[i, :r.max_new_tokens]]
            # truncate at EOS
            if self.cfg.eos_id >= 0 and self.cfg.eos_id in toks:
                toks = toks[:toks.index(self.cfg.eos_id) + 1]
            outs.append(Completion(r.request_id, toks, prefill_ms,
                                   decode_ms, swap_ms, ver.version))
        return outs
