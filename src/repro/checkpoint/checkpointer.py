"""Checkpointing: sharded .npz payloads + JSON index, async save, atomic
commit, reshard-on-restore — for fp training state AND quantized serving
trees.

Layout:
    <dir>/step_000100/
        shard_00000.npz      (flat-key → array chunks owned by this host)
        index.json           (tree structure, shapes, dtypes, quant meta)
        COMMITTED            (written last — a step dir without it, or with
                              an unparseable index.json, is invisible to
                              restore and to the serve reload watcher:
                              torn saves are harmless)

Save is shard-agnostic: every leaf is written as the full logical array
(single-host container) or per-host shards (multi-host: each host writes its
addressable chunks). Restore never assumes the saving topology — it
reassembles from the index and reshards to the *current* mesh
(``mesh=`` on ``restore_serving`` routes through
``distributed.sharding``/``distributed.compat``), which is what makes
elastic restarts (different chip counts) work.

Quantized checkpoints (``save_serving`` with ``quant_meta``) hold the
serving-format ``w_q``/``w_q4``/``w_scale`` trees from ``quant.apply``
natively — int4 nibbles stay packed two-per-int8-byte on disk — and record
``{"format": "quantized", "quant": {bits, method, group_size, report…}}``
in ``index.json`` so restore can refuse a tree that does not match the
requested serve config instead of silently dequantizing garbage.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


class CheckpointMetaError(ValueError):
    """A checkpoint's index.json is unreadable or contradicts the caller's
    expectations (e.g. quantized w4 restored into a w8 serve config)."""


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for keypath, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in keypath)
        out[key] = leaf
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, async_save: bool = True,
                 keep: int = 3):
        self.dir = directory
        self.async_save = async_save
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, params: Any, opt_state: Any):
        # snapshot to host memory synchronously (cheap), write async
        payload = {}
        meta = {"step": step, "trees": {}}
        for name, tree in (("params", params), ("opt", opt_state)):
            flat, _ = _flatten(tree)
            meta["trees"][name] = {"keys": sorted(flat)}
            for k, v in flat.items():
                payload[f"{name}::{k}"] = np.asarray(v)
        self.wait()
        self._dispatch(step, payload, meta)

    def save_serving(self, step: int, params: Any,
                     quant_meta: Optional[Dict[str, Any]] = None):
        """Save a serving weight tree (fp, or a quantized qdict tree from
        ``quant.apply.quantize_params_sharded``).

        ``quant_meta`` marks the checkpoint as quantized and must carry at
        least ``bits`` and ``method`` (plus group_size / QuantReport digest);
        packed int4 codes are written as-is — nibbles stay packed on disk.
        """
        flat, _ = _flatten(params)
        payload = {f"params::{k}": np.asarray(v) for k, v in flat.items()}
        meta = {"step": step, "trees": {"params": {"keys": sorted(flat)}},
                "format": "fp", "quant": None}
        if quant_meta is not None:
            missing = {"bits", "method"} - set(quant_meta)
            if missing:
                raise ValueError(f"quant_meta missing {sorted(missing)}")
            meta["format"] = "quantized"
            meta["quant"] = dict(quant_meta)
        self.wait()
        self._dispatch(step, payload, meta)

    def _dispatch(self, step: int, payload, meta):
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, payload, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, payload, meta)

    def _write(self, step: int, payload, meta):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "shard_00000.npz"), **payload)
        meta["shapes"] = {k: list(v.shape) for k, v in payload.items()}
        meta["dtypes"] = {k: str(v.dtype) for k, v in payload.items()}
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write(str(time.time()))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        """Keep the newest ``keep`` loadable steps; everything else —
        including torn/corrupt step dirs, which ``list_steps`` hides but
        which would otherwise accumulate forever — is deleted. Writers are
        atomic (payload + COMMITTED land in a ``.tmp`` dir, then one
        rename), so a non-``.tmp`` invalid dir is never an in-flight save."""
        keep = set(self.list_steps()[-self.keep:])
        for d in sorted(os.listdir(self.dir)):
            if not d.startswith("step_") or d.endswith(".tmp"):
                continue
            try:
                s = int(d.split("_")[1])
            except ValueError:
                continue
            if s not in keep:
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # --------------------------------------------------------------- restore
    def list_steps(self):
        """Committed, loadable steps. A step dir missing COMMITTED (torn
        save) or whose index.json does not parse (torn/corrupt metadata) is
        skipped — both restore and the serve reload watcher key off this."""
        out = []
        for d in sorted(os.listdir(self.dir)):
            if not d.startswith("step_") or d.endswith(".tmp"):
                continue
            if not os.path.exists(os.path.join(self.dir, d, "COMMITTED")):
                continue
            try:
                with open(os.path.join(self.dir, d, "index.json")) as f:
                    json.load(f)
            except (OSError, ValueError):
                continue
            out.append(int(d.split("_")[1]))
        return out

    def read_meta(self, step: int) -> Dict[str, Any]:
        """Parsed index.json for a committed step."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        if not os.path.exists(os.path.join(d, "COMMITTED")):
            raise CheckpointMetaError(f"step {step}: no COMMITTED marker "
                                      f"(torn save?) in {d}")
        try:
            with open(os.path.join(d, "index.json")) as f:
                return json.load(f)
        except (OSError, ValueError) as e:
            raise CheckpointMetaError(f"step {step}: unreadable index.json "
                                      f"({e})") from e

    def restore(self, step: int, shardings: Optional[Any] = None,
                template: Optional[Tuple[Any, Any]] = None):
        """Returns (params, opt_state, step). ``template`` provides the tree
        structures; ``shardings`` (same structure) reshards onto the current
        mesh (elastic restore)."""
        meta = self.read_meta(step)
        if meta.get("format") == "quantized":
            raise CheckpointMetaError(
                f"step {step} is a quantized serving checkpoint "
                f"(quant={meta.get('quant')}); restore it with "
                f"restore_serving(), not the training-state restore()")
        d = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(d, "shard_00000.npz"))

        def rebuild(name, tmpl, shards):
            flat, treedef = _flatten(tmpl)
            flat_sh, _ = _flatten(shards) if shards is not None else ({}, None)
            leaves = []
            for k in sorted(flat):
                arr = data[f"{name}::{k}"]
                if shards:
                    arr = jax.device_put(arr, flat_sh[k])
                leaves.append(arr)
            keys_sorted = sorted(flat)
            rebuilt = dict(zip(keys_sorted, leaves))
            # reassemble in original flatten order
            ordered = [rebuilt[k] for k in
                       ["/".join(str(getattr(kk, "key",
                                             getattr(kk, "idx", kk)))
                                 for kk in kp)
                        for kp, _ in jax.tree_util.tree_flatten_with_path(
                            tmpl)[0]]]
            return jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(tmpl), ordered)

        if template is None:
            raise ValueError("restore requires a (params, opt) template")
        p_tmpl, o_tmpl = template
        p_sh = o_sh = None
        if shardings is not None:
            p_sh, o_sh = shardings
        params = rebuild("params", p_tmpl, p_sh)
        opt = rebuild("opt", o_tmpl, o_sh)
        return params, opt, step

    def restore_latest(self, shardings=None, template=None):
        steps = self.list_steps()
        if not steps:
            return None
        if template is None:
            return self._restore_raw(steps[-1])
        return self.restore(steps[-1], shardings, template)

    def _restore_raw(self, step: int, to_jax: bool = True):
        """Tree-structure-free restore (single-host): rebuilds nested dicts
        from the flat key paths. ``to_jax=False`` keeps leaves as host numpy
        arrays (for callers that place them on devices themselves — one
        transfer instead of commit-to-default-device-then-reshard)."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(d, "shard_00000.npz"))
        conv = jax.numpy.asarray if to_jax else (lambda a: a)

        def insert(root, path, value):
            node = root
            for p in path[:-1]:
                node = node.setdefault(p, {})
            node[path[-1]] = value

        trees = {"params": {}, "opt": {}}
        for full_key in data.files:
            name, key = full_key.split("::", 1)
            insert(trees[name], key.split("/"), conv(data[full_key]))

        def listify(node):
            """Convert dicts with integer-contiguous keys back to lists."""
            if not isinstance(node, dict):
                return node
            keys = list(node.keys())
            if keys and all(k.isdigit() for k in keys):
                idx = sorted(int(k) for k in keys)
                if idx == list(range(len(idx))):
                    return [listify(node[str(i)]) for i in idx]
            return {k: listify(v) for k, v in node.items()}

        params = listify(trees["params"])
        opt = listify(trees["opt"])
        return params, opt, step

    def restore_serving(self, step: Optional[int] = None,
                        expect: Optional[Dict[str, Any]] = None,
                        mesh=None) -> Tuple[Any, Dict[str, Any], int]:
        """Restore a serving weight tree → ``(params, meta, step)``.

        Loads the newest committed step when ``step`` is None (torn/corrupt
        dirs are invisible). Works for both fp checkpoints (training saves —
        the opt tree is ignored) and native quantized ones.

        ``expect`` carries the serve config's quant expectations
        (``{"quantize_weights": method|None, "weight_bits": int}``): a
        quantized checkpoint whose ``bits``/``method`` metadata mismatch it
        raises :class:`CheckpointMetaError` instead of silently dequantizing
        garbage. fp checkpoints always pass (the caller re-quantizes).

        ``mesh``: reshard-on-restore — every leaf is ``device_put`` onto the
        current mesh's parameter shardings (``distributed.sharding`` rules,
        which cover ``w_q``/``w_q4``/``w_scale`` leaves; bit-exact for any
        device count because the full logical arrays live on disk). Leaves
        stay on the host until the single placing transfer — a full tree is
        never first committed to one default device.
        """
        if step is None:
            steps = self.list_steps()
            if not steps:
                raise FileNotFoundError(f"no committed checkpoint in "
                                        f"{self.dir}")
            step = steps[-1]
        meta = self.read_meta(step)
        quant = meta.get("quant")
        if quant is not None and expect is not None:
            want_m = expect.get("quantize_weights")
            want_b = expect.get("weight_bits")
            if want_m is None:
                raise CheckpointMetaError(
                    f"step {step} holds {quant['method']} w{quant['bits']} "
                    f"weights but the serve config requests unquantized "
                    f"serving")
            if quant.get("bits") != want_b or quant.get("method") != want_m:
                raise CheckpointMetaError(
                    f"step {step} quant metadata mismatch: checkpoint is "
                    f"{quant.get('method')} w{quant.get('bits')}, serve "
                    f"config requests {want_m} w{want_b}")
        params, _, _ = self._restore_raw(step, to_jax=False)
        if mesh is not None:
            from repro.distributed.sharding import reshard_serving_tree
            params = reshard_serving_tree(params, mesh)
        else:
            params = jax.tree_util.tree_map(jax.numpy.asarray, params)
        return params, meta, step
