"""Checkpointing: sharded .npz payloads + JSON index, async save, atomic
commit, reshard-on-restore.

Layout:
    <dir>/step_000100/
        shard_00000.npz      (flat-key → array chunks owned by this host)
        index.json           (tree structure, shapes, dtypes, shard map)
        COMMITTED            (written last — a checkpoint without it is
                              ignored by restore: torn saves are harmless)

Save is shard-agnostic: every leaf is written as the full logical array
(single-host container) or per-host shards (multi-host: each host writes its
addressable chunks). Restore never assumes the saving topology — it
reassembles from the index and reshards to the *current* mesh, which is what
makes elastic restarts (different chip counts) work.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for keypath, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in keypath)
        out[key] = leaf
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, async_save: bool = True,
                 keep: int = 3):
        self.dir = directory
        self.async_save = async_save
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, params: Any, opt_state: Any):
        # snapshot to host memory synchronously (cheap), write async
        payload = {}
        meta = {"step": step, "trees": {}}
        for name, tree in (("params", params), ("opt", opt_state)):
            flat, _ = _flatten(tree)
            meta["trees"][name] = {"keys": sorted(flat)}
            for k, v in flat.items():
                payload[f"{name}::{k}"] = np.asarray(v)
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, payload, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, payload, meta)

    def _write(self, step: int, payload, meta):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "shard_00000.npz"), **payload)
        meta["shapes"] = {k: list(v.shape) for k, v in payload.items()}
        meta["dtypes"] = {k: str(v.dtype) for k, v in payload.items()}
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write(str(time.time()))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # --------------------------------------------------------------- restore
    def list_steps(self):
        out = []
        for d in sorted(os.listdir(self.dir)):
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, d, "COMMITTED")):
                out.append(int(d.split("_")[1]))
        return out

    def restore(self, step: int, shardings: Optional[Any] = None,
                template: Optional[Tuple[Any, Any]] = None):
        """Returns (params, opt_state, step). ``template`` provides the tree
        structures; ``shardings`` (same structure) reshards onto the current
        mesh (elastic restore)."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(d, "shard_00000.npz"))

        def rebuild(name, tmpl, shards):
            flat, treedef = _flatten(tmpl)
            flat_sh, _ = _flatten(shards) if shards is not None else ({}, None)
            leaves = []
            for k in sorted(flat):
                arr = data[f"{name}::{k}"]
                if shards:
                    arr = jax.device_put(arr, flat_sh[k])
                leaves.append(arr)
            keys_sorted = sorted(flat)
            rebuilt = dict(zip(keys_sorted, leaves))
            # reassemble in original flatten order
            ordered = [rebuilt[k] for k in
                       ["/".join(str(getattr(kk, "key",
                                             getattr(kk, "idx", kk)))
                                 for kk in kp)
                        for kp, _ in jax.tree_util.tree_flatten_with_path(
                            tmpl)[0]]]
            return jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(tmpl), ordered)

        if template is None:
            raise ValueError("restore requires a (params, opt) template")
        p_tmpl, o_tmpl = template
        p_sh = o_sh = None
        if shardings is not None:
            p_sh, o_sh = shardings
        params = rebuild("params", p_tmpl, p_sh)
        opt = rebuild("opt", o_tmpl, o_sh)
        return params, opt, step

    def restore_latest(self, shardings=None, template=None):
        steps = self.list_steps()
        if not steps:
            return None
        if template is None:
            return self._restore_raw(steps[-1])
        return self.restore(steps[-1], shardings, template)

    def _restore_raw(self, step: int):
        """Tree-structure-free restore (single-host): rebuilds nested dicts
        from the flat key paths."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(d, "shard_00000.npz"))

        def insert(root, path, value):
            node = root
            for p in path[:-1]:
                node = node.setdefault(p, {})
            node[path[-1]] = value

        trees = {"params": {}, "opt": {}}
        for full_key in data.files:
            name, key = full_key.split("::", 1)
            insert(trees[name], key.split("/"), jax.numpy.asarray(
                data[full_key]))

        def listify(node):
            """Convert dicts with integer-contiguous keys back to lists."""
            if not isinstance(node, dict):
                return node
            keys = list(node.keys())
            if keys and all(k.isdigit() for k in keys):
                idx = sorted(int(k) for k in keys)
                if idx == list(range(len(idx))):
                    return [listify(node[str(i)]) for i in idx]
            return {k: listify(v) for k, v in node.items()}

        params = listify(trees["params"])
        opt = listify(trees["opt"])
        return params, opt, step
