"""Sharded, async, reshard-on-restore checkpointing."""
from repro.checkpoint.checkpointer import Checkpointer  # noqa: F401
