"""Sharded, async, reshard-on-restore checkpointing (fp + quantized)."""
from repro.checkpoint.checkpointer import (Checkpointer,  # noqa: F401
                                           CheckpointMetaError)
