"""Model builder: ArchConfig → init / train_loss / prefill / decode_step.

The returned ``LM`` object is the single interface used by the trainer, the
serving engine, the quantization pipeline, and the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act, shard_logits
from repro.models.layers import init_embedding, rms_norm, init_norm
from repro.models.transformer import (apply_encoder, apply_stack, init_cache,
                                      init_encoder, init_stack, rope_values,
                                      _rope_dim)


def arch_features(cfg) -> Tuple[str, ...]:
    """Sequence-mixer features that make chunked prefill
    tolerance-equivalent (rather than bit-identical) to the monolithic
    path. Keys match ``repro.serving.equivalence.AGREEMENT_BUDGETS`` and
    compose multiplicatively there when features stack (e.g. mixtral is
    ``("sliding_window", "moe")``). An empty tuple means a plain-attention
    dense stack whose chunked prefill is exact."""
    from repro.models.transformer import layer_plan
    plan = layer_plan(cfg)
    feats = []
    if cfg.mla is not None:
        feats.append("mla")
    if cfg.window:
        feats.append("sliding_window")
    if any(moe for _, moe in plan):
        feats.append("moe")
    if any(kind == "m" for kind, _ in plan):
        feats.append("mamba")
    if any(kind == "rwkv" for kind, _ in plan):
        feats.append("rwkv")
    return tuple(feats)


def _xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Sharded-vocab-friendly mean cross-entropy (one-hot dot, fp32)."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    oh = jax.nn.one_hot(labels.clip(0), lg.shape[-1], dtype=jnp.float32)
    ll = jnp.einsum("...v,...v->...", oh, lg)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - ll) * mask) / jnp.maximum(mask.sum(), 1.0)


@dataclasses.dataclass
class LM:
    cfg: Any

    # ----------------------------------------------------------------- init
    def init(self, key) -> Dict[str, Any]:
        dt = jnp.bfloat16 if self.cfg.dtype == "bfloat16" else jnp.float32
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {"embedding": init_embedding(k1, self.cfg.vocab,
                                         self.cfg.d_model, dt),
             "stack": init_stack(k2, self.cfg),
             "final_norm": init_norm(self.cfg.d_model,
                                     plus_one=self.cfg.norm_plus_one)}
        if not self.cfg.tie_embeddings:
            p["lm_head"] = {
                "w": jax.random.normal(
                    k3, (self.cfg.d_model, self.cfg.vocab), dt) * 0.02}
        if self.cfg.is_encdec:
            p["encoder"] = init_encoder(k4, self.cfg)
        return p

    # ------------------------------------------------------------- forward
    def _embed(self, params, tokens):
        x = params["embedding"]["embedding"][tokens]
        if self.cfg.emb_scale:
            x = x * jnp.sqrt(float(self.cfg.d_model)).astype(x.dtype)
        return shard_act(x, ("batch", None, None))

    def _logits(self, params, x):
        x = rms_norm(params["final_norm"], x, plus_one=self.cfg.norm_plus_one)
        if self.cfg.tie_embeddings:
            w = params["embedding"]["embedding"]
            logits = x @ w.T.astype(x.dtype)
        else:
            from repro.models.layers import linear
            logits = linear(params["lm_head"], x)
        return shard_logits(logits)

    def _encode(self, params, batch):
        if not self.cfg.is_encdec:
            return None
        return apply_encoder(params["encoder"], batch["enc_frames"],
                             cfg=self.cfg)

    def forward(self, params, batch, mode: str = "train",
                caches: Optional[dict] = None
                ) -> Tuple[jnp.ndarray, Optional[dict], jnp.ndarray]:
        tokens = batch["tokens"]
        b, s = tokens.shape
        block_tables = caches.get("block_tables") \
            if caches is not None else None
        if mode == "decode":
            pos = caches["pos"]
            # contiguous caches keep ONE scalar clock for the whole batch;
            # paged caches keep a per-slot length vector, so each row gets
            # its own absolute position (rope shapes follow suit)
            positions = pos[None] if pos.ndim == 0 else pos[:, None]
        elif mode == "chunk":
            # partial-prefill continuation: the cache clock is the chunk's
            # start offset; rows live at absolute positions pos..pos+s-1
            pos = caches["pos"]
            positions = pos + jnp.arange(s)
        elif mode == "verify":
            # speculative multi-position verify: ``pos`` is the paged
            # per-slot length vector; row (b, j) sits at absolute
            # position pos[b] + j
            pos = caches["pos"]
            positions = pos[:, None] + jnp.arange(s)
        else:
            pos = jnp.zeros((), jnp.int32)
            positions = jnp.arange(s)
        rope = rope_values(positions, _rope_dim(self.cfg),
                           self.cfg.rope_theta)
        x = self._embed(params, tokens)
        enc_out = batch.get("enc_out")
        if enc_out is None:
            enc_out = self._encode(params, batch)
        x, new_caches, aux = apply_stack(
            params["stack"], x, cfg=self.cfg, rope=rope, mode=mode,
            caches=caches, pos=pos, enc_out=enc_out,
            block_tables=block_tables)
        if new_caches is not None:
            new_caches["pos"] = pos + s
            if block_tables is not None:
                new_caches["block_tables"] = block_tables
            if enc_out is not None:
                new_caches["enc_out"] = enc_out
        logits = self._logits(params, x)
        return logits, new_caches, aux

    # --------------------------------------------------------------- train
    def train_loss(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        logits, _, aux = self.forward(params, batch, mode="train")
        loss = _xent(logits, batch["labels"])
        total = loss + 0.01 * aux
        return total, {"xent": loss, "moe_aux": aux}

    # --------------------------------------------------------------- serve
    def init_cache(self, batch: int, max_len: int,
                   quantize_kv: bool = False) -> dict:
        dt = jnp.bfloat16 if self.cfg.dtype == "bfloat16" else jnp.float32
        c = init_cache(self.cfg, batch, max_len, quantize_kv, dt)
        if self.cfg.is_encdec:
            enc_len = max(1, max_len // self.cfg.enc_ratio)
            c["enc_out"] = jnp.zeros((batch, enc_len, self.cfg.d_model), dt)
        return c

    def prefill(self, params, batch, caches) -> Tuple[jnp.ndarray, dict]:
        logits, caches, _ = self.forward(params, batch, mode="prefill",
                                         caches=caches)
        return logits[:, -1], caches

    def prefill_chunk(self, params, batch, caches
                      ) -> Tuple[jnp.ndarray, dict]:
        """Consume the next ``s`` prompt tokens of a partial prefill.

        ``caches["pos"]`` is the chunk's start offset (0 for a fresh cache);
        the chunk attends over the already-written cache prefix plus itself,
        so feeding a prompt through this in any chunk split yields the same
        cache and last-token logits as one :meth:`prefill` call, bit-exact.
        The offset is traced, not baked in: the contiguous scheduler calls
        this at its shared clock, the paged backend at each slot's own
        prompt offset (including continuations over a gathered shared
        prefix) — one trace per chunk width covers both.
        Single-token chunks are padded to two rows internally: XLA lowers a
        one-row gemm as a matvec whose accumulation order differs from the
        monolithic prefill's, and the dummy row (whose cache write lands one
        past the clock, always overwritten before any masked-in read) is the
        cheapest way to stay on the gemm path. Stacks with recurrent state
        or MoE routing skip the pad — a dummy row would fold into the
        carried state / compete for expert capacity and change real
        outputs; those stacks serve under a measured agreement budget
        rather than bit-identity anyway (see repro.serving.equivalence).
        """
        toks = batch["tokens"]
        pad_ok = not any(f in ("moe", "mamba", "rwkv")
                         for f in arch_features(self.cfg))
        singleton = toks.shape[1] == 1 and pad_ok
        if singleton:
            p0 = caches["pos"]
            toks = jnp.concatenate([toks, toks[:, -1:]], axis=1)
        logits, caches, _ = self.forward(params, {"tokens": toks},
                                         mode="chunk", caches=caches)
        if singleton:
            caches["pos"] = p0 + 1
            return logits[:, 0], caches
        return logits[:, -1], caches

    def arch_features(self) -> Tuple[str, ...]:
        """See :func:`arch_features`."""
        return arch_features(self.cfg)

    def supports_chunked_prefill(self) -> bool:
        """Every decoder-only stack has a chunk-continuation path: plain
        dense attention is bit-exact; MLA / sliding-window / MoE /
        recurrent mixers serve under their measured per-architecture
        agreement budgets (``repro.serving.equivalence``). Only
        encoder-decoder models (round-only scheduling) lack one."""
        return not self.cfg.is_encdec

    def chunked_prefill_exact(self) -> bool:
        """True when chunked prefill reproduces the monolithic path
        bit-for-bit (plain-attention dense stacks)."""
        return self.supports_chunked_prefill() \
            and not arch_features(self.cfg)

    def has_recurrent_state(self) -> bool:
        """True when the cache carries recurrent (non-positional) state —
        mamba conv/ssm carries or rwkv token-shift/wkv carries. The
        serving side-cache allocator must not reuse such caches across
        admissions (stale state is not masked out the way stale KV rows
        are)."""
        feats = arch_features(self.cfg)
        return "mamba" in feats or "rwkv" in feats

    def decode_step(self, params, tokens, caches
                    ) -> Tuple[jnp.ndarray, dict]:
        """tokens: (B, 1) — one new token per sequence."""
        batch = {"tokens": tokens, "enc_out": caches.get("enc_out")}
        logits, caches, _ = self.forward(params, batch, mode="decode",
                                         caches=caches)
        return logits[:, -1], caches

    def verify_step(self, params, tokens, caches
                    ) -> Tuple[jnp.ndarray, dict]:
        """tokens: (B, S) — S-token runs written and scored at per-slot
        absolute positions ``pos[b] .. pos[b]+S-1`` on the paged cache.

        Returns ALL S per-position logits ``(B, S, vocab)``: row ``j``
        conditions on everything through position ``pos[b]+j``, so it is
        exactly the logits a lockstep decode step would produce there —
        the speculative verifier consumes every row (unlike prefill /
        decode, which return only the last)."""
        batch = {"tokens": tokens, "enc_out": caches.get("enc_out")}
        logits, caches, _ = self.forward(params, batch, mode="verify",
                                         caches=caches)
        return logits, caches

    # ---------------------------------------------------------------- specs
    def input_specs(self, shape) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for one step's data inputs."""
        b, s = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if shape.kind == "train":
            spec = {"tokens": tok, "labels": tok}
        elif shape.kind == "prefill":
            spec = {"tokens": tok}
        else:  # decode
            spec = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        if self.cfg.is_encdec and shape.kind != "decode":
            dt = jnp.bfloat16 if self.cfg.dtype == "bfloat16" else jnp.float32
            spec["enc_frames"] = jax.ShapeDtypeStruct(
                (b, max(1, s // self.cfg.enc_ratio), self.cfg.d_model), dt)
        return spec

    def param_shapes(self, key=None) -> Any:
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(self.init, key)

    def cache_shapes(self, batch: int, max_len: int,
                     quantize_kv: bool = False) -> Any:
        return jax.eval_shape(
            lambda: self.init_cache(batch, max_len, quantize_kv=quantize_kv))


def build_model(cfg) -> LM:
    return LM(cfg=cfg)
