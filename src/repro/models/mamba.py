"""Mamba (S6 selective SSM) block — the Jamba hybrid's sequence mixer.

Training/prefill uses an associative scan over time on the diagonal SSM
recurrence  h_t = a_t ⊙ h_{t-1} + b_t  (a_t = exp(Δ_t·A), b_t = Δ_t·B_t·x_t),
O(log S) depth, sub-quadratic in sequence length. Decode is a single-step
state update (O(1) per token — why the hybrid runs the 500k-decode shape).

Shapes follow mamba-1: d_inner = expand·d_model, depthwise causal conv
(d_conv), data-dependent Δ/B/C, learned A (d_inner, d_state) and D skip.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _init_dense, linear


def init_mamba(key, d_model: int, d_state: int = 16, d_conv: int = 4,
               expand: int = 2, dt_rank: Optional[int] = None) -> Dict:
    d_inner = expand * d_model
    dt_rank = dt_rank or max(1, d_model // 16)
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None],
                 (d_inner, 1))
    return {
        "in_proj": _init_dense(ks[0], d_model, 2 * d_inner),
        "conv_w": jax.random.normal(ks[1], (d_conv, d_inner), jnp.float32)
        * (1.0 / jnp.sqrt(d_conv)),
        "conv_b": jnp.zeros((d_inner,), jnp.float32),
        "x_proj": _init_dense(ks[2], d_inner, dt_rank + 2 * d_state),
        "dt_proj": _init_dense(ks[3], dt_rank, d_inner),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (d_inner,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out_proj": _init_dense(ks[5], d_inner, d_model),
    }


def _ssm_inputs(params, xc, dt_rank: int, d_state: int):
    """xc: (..., d_inner) post-conv. Returns (a, bx, c) per position."""
    dbc = linear(params["x_proj"], xc)
    dt = dbc[..., :dt_rank]
    b = dbc[..., dt_rank:dt_rank + d_state]
    c = dbc[..., dt_rank + d_state:]
    dt = jax.nn.softplus(linear(params["dt_proj"], dt)
                         + params["dt_bias"].astype(xc.dtype))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))          # (DI, N)
    a_t = jnp.exp(dt[..., None].astype(jnp.float32) * a)       # (..., DI, N)
    bx = (dt * xc)[..., None].astype(jnp.float32) * \
        b[..., None, :].astype(jnp.float32)                    # (..., DI, N)
    return a_t, bx, c


def _conv_train(params, x):
    """Depthwise causal conv over (B, S, DI)."""
    d_conv = params["conv_w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] *
              params["conv_w"][i][None, None].astype(x.dtype)
              for i in range(d_conv))
    return out + params["conv_b"].astype(x.dtype)


def _chunk_scan(a_t, bx, h0):
    """h_t = a_t·h_{t-1} + bx_t over one chunk, given entry state h0.

    a_t, bx: (B, C, DI, N); h0: (B, DI, N). Associative scan within the
    chunk plus the decayed h0 contribution (cumprod of a via log-space).
    Returns (h_all (B, C, DI, N), h_last)."""
    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl
    _, h = jax.lax.associative_scan(comb, (a_t, bx), axis=1)
    cum = jnp.cumprod(a_t, axis=1)
    h = h + cum * h0[:, None]
    return h, h[:, -1]


def mamba(params, x: jnp.ndarray, *, d_state: int = 16,
          state: Optional[Dict] = None, mode: str = "train",
          chunk: int = 512, unroll: bool = False
          ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x: (B, S, D) for train/prefill; (B, 1, D) for decode.

    Train/prefill processes the sequence in ``chunk``-sized pieces
    (lax.scan over chunks, associative scan within) so the (B, C, DI, N)
    state tensor — not (B, S, DI, N) — bounds the working set.
    """
    b, s, d = x.shape
    d_inner = params["dt_bias"].shape[0]
    dt_rank = params["dt_proj"]["w"].shape[0]
    xz = linear(params["in_proj"], x)
    xr, z = xz[..., :d_inner], xz[..., d_inner:]

    if mode in ("train", "prefill"):
        xc = jax.nn.silu(_conv_train(params, xr))
        h0 = jnp.zeros((b, d_inner, d_state), jnp.float32)
        if s > chunk and s % chunk == 0:
            nc = s // chunk
            xc_r = xc.reshape(b, nc, chunk, d_inner).swapaxes(0, 1)

            def body(h_in, xc_c):
                a_t, bx, c = _ssm_inputs(params, xc_c, dt_rank, d_state)
                h_all, h_out = _chunk_scan(a_t, bx, h_in)
                yc = jnp.einsum("bsdn,bsn->bsd", h_all,
                                c.astype(jnp.float32))
                return h_out, yc.astype(x.dtype)

            if not unroll:
                # remat per chunk: the scan's backward otherwise stores the
                # (B, C, DI, N) f32 chunk-state residuals for every chunk —
                # tens of GB/chip at jamba scale (found by the dry-run).
                body = jax.checkpoint(body)
            if unroll:
                ys = []
                h_last = h0
                for i in range(nc):
                    h_last, yc = body(h_last, xc_r[i])
                    ys.append(yc)
                ys = jnp.stack(ys)
            else:
                h_last, ys = jax.lax.scan(body, h0, xc_r)
            y = ys.swapaxes(0, 1).reshape(b, s, d_inner)
        else:
            a_t, bx, c = _ssm_inputs(params, xc, dt_rank, d_state)
            h_all, h_last = _chunk_scan(a_t, bx, h0)
            y = jnp.einsum("bsdn,bsn->bsd", h_all, c.astype(jnp.float32))
        y = y.astype(x.dtype) + xc * params["d_skip"].astype(x.dtype)
        new_state = None
        if mode == "prefill":
            d_conv = params["conv_w"].shape[0]
            new_state = {
                "h": h_last,                                    # (B, DI, N)
                "conv": xr[:, -(d_conv - 1):, :],
            }
    elif mode == "chunk":
        # partial-prefill continuation: the depthwise conv reads its left
        # context from the carried ``conv`` tail instead of zero padding,
        # and the associative scan enters at the carried ``h`` — the
        # monolithic prefill recurrence up to float reassociation of the
        # scan's chunk-split grouping, served under the measured "mamba"
        # agreement budget (see repro.serving.equivalence).
        d_conv = params["conv_w"].shape[0]
        xp = jnp.concatenate([state["conv"].astype(xr.dtype), xr], axis=1)
        conv = sum(xp[:, i:i + s, :] *
                   params["conv_w"][i][None, None].astype(xr.dtype)
                   for i in range(d_conv))
        xc = jax.nn.silu(conv + params["conv_b"].astype(xr.dtype))
        a_t, bx, c = _ssm_inputs(params, xc, dt_rank, d_state)
        h_all, h_last = _chunk_scan(a_t, bx, state["h"])
        y = jnp.einsum("bsdn,bsn->bsd", h_all, c.astype(jnp.float32))
        y = y.astype(x.dtype) + xc * params["d_skip"].astype(x.dtype)
        new_state = {"h": h_last,
                     "conv": xp[:, s:, :].astype(jnp.float32)}
    else:  # decode: one token
        d_conv = params["conv_w"].shape[0]
        conv_buf = jnp.concatenate([state["conv"], xr], axis=1)  # (B,dc,DI)
        xc = jnp.einsum("bcd,cd->bd", conv_buf.astype(jnp.float32),
                        params["conv_w"].astype(jnp.float32))
        xc = jax.nn.silu(xc + params["conv_b"]).astype(x.dtype)[:, None]
        a_t, bx, c = _ssm_inputs(params, xc, dt_rank, d_state)
        h = a_t[:, 0] * state["h"] + bx[:, 0]                  # (B, DI, N)
        y = jnp.einsum("bdn,bn->bd", h, c[:, 0].astype(jnp.float32))
        y = y[:, None].astype(x.dtype) + xc * params["d_skip"].astype(x.dtype)
        new_state = {"h": h, "conv": conv_buf[:, 1:]}

    out = jax.nn.silu(z) * y
    return linear(params["out_proj"], out), new_state


def init_mamba_state(batch: int, d_model: int, d_state: int = 16,
                     d_conv: int = 4, expand: int = 2) -> Dict:
    d_inner = expand * d_model
    return {"h": jnp.zeros((batch, d_inner, d_state), jnp.float32),
            "conv": jnp.zeros((batch, d_conv - 1, d_inner), jnp.float32)}
