"""Feed-forward variants: SwiGLU (llama-family), GeGLU (gemma), ReLU/GELU."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.layers import _init_dense, linear


def init_ffn(key, d_model: int, d_ff: int, kind: str = "swiglu") -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {"wi": _init_dense(k1, d_model, d_ff),
                "wg": _init_dense(k2, d_model, d_ff),
                "wdown": _init_dense(k3, d_ff, d_model)}
    return {"wi": _init_dense(k1, d_model, d_ff),
            "wdown": _init_dense(k3, d_ff, d_model)}


def ffn(params, x: jnp.ndarray, kind: str = "swiglu") -> jnp.ndarray:
    if kind == "swiglu":
        h = jax.nn.silu(linear(params["wg"], x)) * linear(params["wi"], x)
    elif kind == "geglu":
        h = jax.nn.gelu(linear(params["wg"], x), approximate=True) * \
            linear(params["wi"], x)
    elif kind == "gelu":
        h = jax.nn.gelu(linear(params["wi"], x), approximate=True)
    else:  # relu
        h = jax.nn.relu(linear(params["wi"], x))
    from repro.distributed.sharding import shard_act
    h = shard_act(h, ("batch", None, "ff"))
    return linear(params["wdown"], h)
