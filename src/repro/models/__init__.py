"""Model zoo: composable JAX definitions for the assigned architectures."""
