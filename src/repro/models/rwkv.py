"""RWKV-6 ("Finch") block: attention-free time mix with data-dependent
per-channel decay, plus the squared-ReLU channel mix.

Two equivalent sequence-mix implementations:
* ``wkv_scan``    — per-step recurrence (the oracle; also the decode path).
* ``wkv_chunked`` — chunkwise-parallel form (intra-chunk matmuls + one state
  carry per chunk): the TPU-friendly training path. Per-channel log-decays
  factorize the inter-position decay exp(b_{i-1} − b_j) into q·k form; with
  the per-step log-decay clamped to ≥ −2 and chunk 32, every intermediate
  stays finite in f32 (documented trade-off in DESIGN.md — real RWKV allows
  faster decay; tests verify chunked == scan in the clamped regime).

Recurrence per head (state S: (D_k, D_v)):
    o_t = r_t · (S_{t-1} + diag(u)·k_tᵀ v_t)
    S_t = diag(w_t)·S_{t-1} + k_tᵀ v_t
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _init_dense, init_norm, linear, rms_norm

LOG_W_MIN = -2.0   # per-step log-decay clamp (see module docstring)


def init_rwkv_timemix(key, d_model: int, head_dim: int = 64,
                      decay_lora: int = 64) -> Dict:
    ks = jax.random.split(key, 10)
    h = d_model // head_dim
    p = {
        # NB: the decay stream's lerp factor is keyed "d", not "w" — "w" is
        # reserved for matmul kernels (quantization/sharding conventions).
        "mu": {s: jnp.full((d_model,), 0.5, jnp.float32)
               for s in ("r", "k", "v", "g", "d")},
        "wr": _init_dense(ks[0], d_model, d_model),
        "wk": _init_dense(ks[1], d_model, d_model),
        "wv": _init_dense(ks[2], d_model, d_model),
        "wg": _init_dense(ks[3], d_model, d_model),
        "w_lora_a": _init_dense(ks[4], d_model, decay_lora, scale=0.01),
        "w_lora_b": _init_dense(ks[5], decay_lora, d_model, scale=0.01),
        "w0": jnp.full((d_model,), -1.0, jnp.float32),
        "u": jax.random.normal(ks[6], (h, head_dim), jnp.float32) * 0.1,
        "ln_x": init_norm(d_model),
        "wo": _init_dense(ks[7], d_model, d_model),
    }
    return p


def _token_shift(x, x_prev):
    """Shift right by one; position 0 sees ``x_prev`` (zeros at seq start)."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _mix_streams(p, x, xx):
    """RWKV6-style data-dependent lerp for the five streams."""
    dx = xx - x
    outs = {}
    for s in ("r", "k", "v", "g"):
        outs[s] = x + dx * p["mu"][s].astype(x.dtype)
    outs["w"] = x + dx * p["mu"]["d"].astype(x.dtype)
    # decay gets the extra data-dependent LoRA term (the "Finch" novelty)
    lora = jnp.tanh(linear(p["w_lora_a"], outs["w"]))
    outs["w_raw"] = p["w0"].astype(x.dtype) + linear(p["w_lora_b"], lora)
    return outs


def _heads(x, head_dim):
    b, s, d = x.shape
    return x.reshape(b, s, d // head_dim, head_dim).swapaxes(1, 2)


def wkv_scan(r, k, v, logw, u, s0):
    """Oracle/decode path. r/k/v/logw: (B, H, S, D); u: (H, D);
    s0: (B, H, D, D). Returns (o, s_final)."""
    def step(s, inp):
        rt, kt, vt, lwt = inp                           # (B, H, D)
        kv = kt[..., :, None] * vt[..., None, :]        # (B, H, D, D)
        o = jnp.einsum("bhd,bhdn->bhn", rt, s + u[None, :, :, None] * kv)
        s = jnp.exp(lwt)[..., None] * s + kv
        return s, o
    xs = tuple(a.swapaxes(0, 2).swapaxes(1, 2) for a in (r, k, v, logw))
    # now (S, B, H, D)
    s_fin, o = jax.lax.scan(step, s0, xs)
    return o.swapaxes(0, 1).swapaxes(1, 2), s_fin       # (B, H, S, D)


def wkv_chunked(r, k, v, logw, u, s0, chunk: int = 32,
                unroll: bool = False):
    """Chunkwise-parallel WKV. Same signature as wkv_scan."""
    b, h, s, d = r.shape
    assert s % chunk == 0, f"seq {s} % chunk {chunk}"
    n = s // chunk

    def per_chunk(state, inp):
        rc, kc, vc, lwc = inp                            # (B, H, L, D)
        bcs = jnp.cumsum(lwc, axis=2)                    # inclusive b_i
        b_prev = bcs - lwc                               # b_{i-1}
        q = rc * jnp.exp(b_prev)
        o_inter = jnp.einsum("bhid,bhdn->bhin", q, state)
        kx = kc * jnp.exp(-bcs)
        att = jnp.einsum("bhid,bhjd->bhij", q, kx)
        ii = jnp.arange(chunk)
        att = jnp.where(ii[:, None] > ii[None, :], att, 0.0)
        o_intra = jnp.einsum("bhij,bhjn->bhin", att, vc)
        cdiag = jnp.einsum("bhid,hd,bhid->bhi", rc, u, kc)
        o = o_inter + o_intra + cdiag[..., None] * vc
        kz = kc * jnp.exp(bcs[:, :, -1:, :] - bcs)
        state = jnp.exp(bcs[:, :, -1, :])[..., None] * state + \
            jnp.einsum("bhjd,bhjn->bhdn", kz, vc)
        return state, o

    def resh(a):                                          # (n, B, H, L, D)
        return a.reshape(b, h, n, chunk, d).swapaxes(0, 2).swapaxes(1, 2)

    xs = tuple(resh(a) for a in (r, k, v, logw))
    if unroll:
        os_ = []
        s_fin = s0
        for i in range(n):
            s_fin, oc = per_chunk(s_fin, tuple(a[i] for a in xs))
            os_.append(oc)
        o = jnp.stack(os_)
    else:
        # remat per chunk: bounds backward residuals to one chunk's
        # (B,H,L,L)+(B,H,L,D) working set instead of all n chunks'
        s_fin, o = jax.lax.scan(jax.checkpoint(per_chunk), s0, xs)
    o = o.swapaxes(1, 2).swapaxes(0, 2).reshape(b, h, s, d)
    return o, s_fin


def rwkv_timemix(p, x, *, head_dim: int = 64, state: Optional[Dict] = None,
                 mode: str = "train", chunk: int = 32, unroll: bool = False
                 ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    b, s, d = x.shape
    h = d // head_dim
    # train/prefill start a sequence: entry state is zeros by definition
    # (a reused serving side cache may hold a retired request's state, so
    # prefill must not read it). "chunk" is the prefill *continuation*: it
    # folds the carried token-shift row and wkv state across the chunk
    # boundary — the monolithic recurrence up to float reassociation of
    # the scan grouping (the measured "rwkv" agreement budget).
    seq_start = mode in ("train", "prefill") or state is None
    x_prev = jnp.zeros_like(x[:, 0]) if seq_start \
        else state["x_tm"].astype(x.dtype)
    xx = _token_shift(x, x_prev)
    mix = _mix_streams(p, x, xx)
    r = _heads(linear(p["wr"], mix["r"]), head_dim)
    k = _heads(linear(p["wk"], mix["k"]), head_dim)
    v = _heads(linear(p["wv"], mix["v"]), head_dim)
    g = jax.nn.silu(linear(p["wg"], mix["g"]))
    logw = jnp.clip(-jnp.exp(mix["w_raw"].astype(jnp.float32)),
                    LOG_W_MIN, -1e-4)
    logw = _heads(logw, head_dim)
    u = p["u"].astype(jnp.float32)

    s0 = jnp.zeros((b, h, head_dim, head_dim), jnp.float32) if seq_start \
        else state["wkv"]
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    if mode == "decode" or s == 1:
        o, s_fin = wkv_scan(rf, kf, vf, logw, u, s0)
    elif mode in ("train", "prefill", "chunk"):
        if s % chunk == 0:
            o, s_fin = wkv_chunked(rf, kf, vf, logw, u, s0, chunk, unroll)
        else:
            o, s_fin = wkv_scan(rf, kf, vf, logw, u, s0)
    o = o.swapaxes(1, 2).reshape(b, s, d).astype(x.dtype)
    o = rms_norm(p["ln_x"], o) * g
    out = linear(p["wo"], o)
    new_state = None
    if mode in ("prefill", "decode", "chunk"):
        new_state = {"x_tm": x[:, -1], "wkv": s_fin}
    return out, new_state


def init_rwkv_channelmix(key, d_model: int, d_ff: int) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu": {s: jnp.full((d_model,), 0.5, jnp.float32) for s in ("k", "r")},
        "wk": _init_dense(k1, d_model, d_ff),
        "wv": _init_dense(k2, d_ff, d_model),
        "wr": _init_dense(k3, d_model, d_model),
    }


def rwkv_channelmix(p, x, *, state: Optional[Dict] = None,
                    mode: str = "train") -> Tuple[jnp.ndarray, Optional[Dict]]:
    seq_start = mode in ("train", "prefill") or state is None
    x_prev = jnp.zeros_like(x[:, 0]) if seq_start \
        else state["x_cm"].astype(x.dtype)
    xx = _token_shift(x, x_prev)
    dx = xx - x
    xk = x + dx * p["mu"]["k"].astype(x.dtype)
    xr = x + dx * p["mu"]["r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(linear(p["wk"], xk)))
    out = jax.nn.sigmoid(linear(p["wr"], xr)) * linear(p["wv"], kk)
    new_state = {"x_cm": x[:, -1]} \
        if mode in ("prefill", "decode", "chunk") else None
    return out, new_state
