"""Block assembly: uniform decoder stacks, hybrid (Jamba) period stacks,
RWKV stacks, and the encoder-decoder wiring — all scan-over-layers with
stacked parameters (small HLO, fast SPMD partitioning) and optional remat.

Block kinds:
  "a"    attention block   : x += attn(ln1(x)); x += ffn_or_moe(ln2(x))
  "m"    mamba block       : x += mamba(ln1(x)); x += ffn_or_moe(ln2(x))
  "rwkv" rwkv block        : x += timemix(ln1(x)); x += channelmix(ln2(x))
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act
from repro.models import attention as attn_lib
from repro.models import ffn as ffn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models.layers import init_norm, rms_norm


# ---------------------------------------------------------------------------
# rope helper: per-position values, computed on the fly (no 500k tables)
# ---------------------------------------------------------------------------

def rope_values(positions: jnp.ndarray, rope_dim: int, theta: float,
                dtype=jnp.float32) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (S,) shared across the batch, or (B, S) per-row (paged
    decode). Returns cos/sin of shape ``positions.shape + (rope_dim//2,)``;
    the per-position multiply is identical either way, so a row at absolute
    position p gets bit-identical rotary values through both shapes."""
    inv = 1.0 / (theta ** (jnp.arange(0, rope_dim, 2, dtype=jnp.float32)
                           / rope_dim))
    freqs = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(freqs).astype(dtype), jnp.sin(freqs).astype(dtype)


def _rope_dim(cfg) -> int:
    return cfg.mla.rope_dim if cfg.mla is not None else cfg.head_dim


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def init_block(key, cfg, kind: str, use_moe: bool,
               cross: bool = False) -> Dict[str, Any]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    po = cfg.norm_plus_one
    p: Dict[str, Any] = {"ln1": init_norm(d, plus_one=po),
                         "ln2": init_norm(d, plus_one=po)}
    if cross:
        p["xln"] = init_norm(d, plus_one=po)
        p["xattn"] = attn_lib.init_cross_attention(k4, cfg)
    if kind == "a":
        p["attn"] = attn_lib.init_attention(k1, cfg)
    elif kind == "m":
        m = cfg.mamba
        p["mixer"] = mamba_lib.init_mamba(
            k1, d, d_state=m.d_state, d_conv=m.d_conv, expand=m.expand,
            dt_rank=m.dt_rank)
    elif kind == "rwkv":
        p["tm"] = rwkv_lib.init_rwkv_timemix(k1, d, cfg.rwkv_head_dim)
        p["cm"] = rwkv_lib.init_rwkv_channelmix(k2, d, cfg.d_ff)
        return p
    else:
        raise ValueError(kind)
    if use_moe:
        p["moe"] = moe_lib.init_moe(k3, d, cfg.d_ff, cfg.moe.n_experts,
                                    cfg.ffn_kind)
    else:
        p["ffn"] = ffn_lib.init_ffn(k3, d, cfg.d_ff, cfg.ffn_kind)
    return p


def apply_block(p, x, *, cfg, kind: str, use_moe: bool, rope, mode: str,
                cache: Optional[dict], pos,
                enc_out: Optional[jnp.ndarray] = None,
                block_tables: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Optional[dict], jnp.ndarray]:
    """Returns (x, new_cache, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    if mode == "verify" and kind != "a":
        # the speculative multi-position verify scores every draft row as
        # if it were a lockstep decode step; recurrent mixers would need a
        # per-row state rewind to do that, so verify stays attention-only
        raise NotImplementedError(
            f"{mode!r} mode is not implemented for {kind!r} blocks")
    if kind == "rwkv":
        h, st_tm = rwkv_lib.rwkv_timemix(
            p["tm"], rms_norm(p["ln1"], x, plus_one=cfg.norm_plus_one),
            head_dim=cfg.rwkv_head_dim, chunk=cfg.rwkv_chunk,
            unroll=cfg.unroll_chunks,
            state=cache, mode=mode)
        x = x + h
        h, st_cm = rwkv_lib.rwkv_channelmix(
            p["cm"], rms_norm(p["ln2"], x, plus_one=cfg.norm_plus_one),
            state=cache, mode=mode)
        x = x + h
        new_cache = None
        if st_tm is not None:
            new_cache = {**st_tm, **(st_cm or {})}
        return x, new_cache, aux

    if kind == "a":
        h, new_cache = attn_lib.attention(
            p["attn"], rms_norm(p["ln1"], x, plus_one=cfg.norm_plus_one),
            cfg=cfg, rope=rope, mode=mode, cache=cache, pos=pos,
            block_tables=block_tables)
    else:  # mamba
        h, new_cache = mamba_lib.mamba(
            p["mixer"], rms_norm(p["ln1"], x, plus_one=cfg.norm_plus_one),
            d_state=cfg.mamba.d_state, state=cache, mode=mode,
            chunk=cfg.mamba_chunk, unroll=cfg.unroll_chunks)
    x = x + h
    x = shard_act(x, ("batch", None, None))
    if "xattn" in p:
        hx = attn_lib.cross_attention(
            p["xattn"], rms_norm(p["xln"], x, plus_one=cfg.norm_plus_one),
            enc_out, cfg=cfg)
        x = x + hx
    h2 = rms_norm(p["ln2"], x, plus_one=cfg.norm_plus_one)
    if use_moe:
        # dropless at decode AND verify: with no capacity competition each
        # token's expert mix is batch-independent, which keeps speculative
        # verify rows bit-identical to the decode steps they stand in for.
        # Prefill/chunk use capacity routing: a chunked prefill therefore
        # routes per chunk, and capacity competition (hence token dropping)
        # depends on the chunk split — that chunk-split-dependence is the
        # measured "moe" agreement budget (see repro.serving.equivalence).
        h2, aux = moe_lib.moe_ffn(p["moe"], h2, n_experts=cfg.moe.n_experts,
                                  top_k=cfg.moe.top_k, kind=cfg.ffn_kind,
                                  capacity_factor=cfg.moe.capacity_factor,
                                  dropless=(mode in ("decode", "verify")))
    else:
        h2 = ffn_lib.ffn(p["ffn"], h2, cfg.ffn_kind)
    x = x + h2
    x = shard_act(x, ("batch", None, None))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# layer pattern
# ---------------------------------------------------------------------------

def layer_plan(cfg) -> Tuple[Tuple[str, bool], ...]:
    """((kind, use_moe) per layer in one stack period, n_periods)."""
    if cfg.rwkv:
        pattern = (("rwkv", False),)
    elif cfg.block_pattern is not None:
        period = len(cfg.block_pattern)
        moe_every = cfg.moe.every if cfg.moe else 0
        pattern = tuple(
            (k, bool(moe_every) and (i % moe_every == moe_every - 1))
            for i, k in enumerate(cfg.block_pattern))
        assert cfg.n_layers % period == 0
    elif cfg.moe is not None and cfg.moe.every > 1:
        ev = cfg.moe.every
        pattern = tuple(("a", i % ev == ev - 1) for i in range(ev))
    elif cfg.moe is not None:
        pattern = (("a", True),)
    else:
        pattern = (("a", False),)
    return pattern


def n_periods(cfg) -> int:
    return cfg.n_layers // len(layer_plan(cfg))


# ---------------------------------------------------------------------------
# stacked init / apply
# ---------------------------------------------------------------------------

def unstack_stack(stack: Dict[str, Any], periods: int) -> Dict[str, Any]:
    """{"periods": stacked} → {"list": [...]} (for real-quantized serving,
    where QuantizedTensor leaves cannot be scanned over)."""
    if "list" in stack:
        return stack
    return {"list": [jax.tree_util.tree_map(lambda a: a[i],
                                            stack["periods"])
                     for i in range(periods)]}


def init_stack(key, cfg) -> Dict[str, Any]:
    pattern = layer_plan(cfg)
    periods = n_periods(cfg)
    keys = jax.random.split(key, periods)

    def one_period(k):
        ks = jax.random.split(k, len(pattern))
        return {f"b{i}": init_block(ks[i], cfg, kind, moe,
                                    cross=cfg.is_encdec)
                for i, (kind, moe) in enumerate(pattern)}

    if cfg.scan_layers and periods > 1:
        return {"periods": jax.vmap(one_period)(keys)}
    return {"list": [one_period(k) for k in keys]}


def init_layer_cache(cfg, batch: int, max_len: int, kind: str,
                     quantize_kv: bool = False, dtype=jnp.bfloat16):
    if kind == "rwkv":
        d = cfg.d_model
        h = d // cfg.rwkv_head_dim
        return {"x_tm": jnp.zeros((batch, d), dtype),
                "x_cm": jnp.zeros((batch, d), dtype),
                "wkv": jnp.zeros((batch, h, cfg.rwkv_head_dim,
                                  cfg.rwkv_head_dim), jnp.float32)}
    if kind == "m":
        m = cfg.mamba
        return mamba_lib.init_mamba_state(batch, cfg.d_model, m.d_state,
                                          m.d_conv, m.expand)
    if cfg.mla is not None:
        return attn_lib.init_mla_cache(batch, max_len, cfg, dtype)
    return attn_lib.init_kv_cache(batch, max_len, cfg.n_kv_heads,
                                  cfg.head_dim, dtype, quantize_kv,
                                  cfg.window)


def init_cache(cfg, batch: int, max_len: int, quantize_kv: bool = False,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    pattern = layer_plan(cfg)
    periods = n_periods(cfg)

    def one_period():
        return {f"b{i}": init_layer_cache(cfg, batch, max_len, kind,
                                          quantize_kv, dtype)
                for i, (kind, _) in enumerate(pattern)}

    if cfg.scan_layers and periods > 1:
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (periods,) + x.shape),
            one_period())
        caches = {"periods": stacked}
    else:
        caches = {"list": [one_period() for _ in range(periods)]}
    caches["pos"] = jnp.zeros((), jnp.int32)
    return caches


def apply_stack(stack, x, *, cfg, rope, mode: str, caches, pos,
                enc_out: Optional[jnp.ndarray] = None,
                block_tables: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    """Run all layers. Returns (x, new_caches, moe_aux_mean)."""
    pattern = layer_plan(cfg)

    def run_period(pp, xin, pcache):
        aux_sum = jnp.zeros((), jnp.float32)
        new_c = {}
        for i, (kind, moe) in enumerate(pattern):
            c_in = None if pcache is None else pcache.get(f"b{i}")
            xin, c_out, aux = apply_block(
                pp[f"b{i}"], xin, cfg=cfg, kind=kind, use_moe=moe, rope=rope,
                mode=mode, cache=c_in, pos=pos, enc_out=enc_out,
                block_tables=block_tables)
            aux_sum += aux
            if c_out is not None:
                new_c[f"b{i}"] = c_out
        return xin, (new_c if new_c else None), aux_sum

    needs_cache = mode in ("prefill", "decode", "chunk", "verify")
    if "periods" in stack:
        pcaches = caches["periods"] if needs_cache else None

        def body(xc, per):
            pp, pc = per
            xout, new_c, aux = run_period(pp, xc,
                                          pc if needs_cache else None)
            if not needs_cache:
                new_c = 0.0
            elif new_c is None:
                new_c = pc
            return xout, (new_c, aux)

        if cfg.remat:
            body = jax.checkpoint(body)
        periods = n_periods(cfg)
        xs = (stack["periods"],
              pcaches if pcaches is not None
              else jnp.zeros((periods,), jnp.float32))
        x, (new_caches, auxs) = jax.lax.scan(body, x, xs)
        aux = jnp.mean(auxs)
        out_caches = {"periods": new_caches} if needs_cache else None
    else:
        new_list = []
        aux_total = jnp.zeros((), jnp.float32)
        runp = jax.checkpoint(run_period) if cfg.remat else run_period
        for i, pp in enumerate(stack["list"]):
            pc = caches["list"][i] if needs_cache else None
            x, new_c, aux_i = runp(pp, x, pc)
            aux_total += aux_i
            new_list.append(new_c if new_c is not None else pc)
        aux = aux_total / max(len(stack["list"]), 1)
        out_caches = {"list": new_list} if needs_cache else None
    return x, out_caches, aux


# ---------------------------------------------------------------------------
# encoder (seamless: bidirectional over stubbed frame embeddings)
# ---------------------------------------------------------------------------

def init_encoder(key, cfg) -> Dict[str, Any]:
    keys = jax.random.split(key, cfg.encoder_layers)

    def one(k):
        ks = jax.random.split(k, 3)
        return {"ln1": init_norm(cfg.d_model),
                "attn": attn_lib.init_cross_attention(ks[0], cfg),  # full MHA
                "ln2": init_norm(cfg.d_model),
                "ffn": ffn_lib.init_ffn(ks[1], cfg.d_model, cfg.d_ff,
                                        cfg.ffn_kind)}

    return {"layers": jax.vmap(one)(keys),
            "final_norm": init_norm(cfg.d_model)}


def apply_encoder(enc, frames, *, cfg) -> jnp.ndarray:
    """frames: (B, T, d) precomputed frontend embeddings (stub)."""
    s = frames.shape[1]
    cos, sin = rope_values(jnp.arange(s), cfg.head_dim, cfg.rope_theta)

    def body(x, pp):
        h = rms_norm(pp["ln1"], x)
        h = attn_lib.cross_attention(pp["attn"], h, h, cfg=cfg)
        x = x + h
        h = ffn_lib.ffn(pp["ffn"], rms_norm(pp["ln2"], x), cfg.ffn_kind)
        return x + h, 0.0

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, frames, enc["layers"])
    return rms_norm(enc["final_norm"], x)


