"""Attention: GQA / MQA / sliding-window / MLA, with KV caches for decode.

Grouped-query attention uses the grouped einsum form (no materialized KV
repeat). Sliding-window decode keeps a ring-buffer cache of window size
(O(window) state — the sub-quadratic path mixtral uses for long contexts).
MLA (MiniCPM3/DeepSeek-style) caches the *compressed* c_kv + shared RoPE key,
reconstructing K/V per step.

KV caches optionally store int8 codes with per-(token, head) scales
(``quantize_kv``): a serving-memory optimization SQuant's weight format pairs
with (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act
from repro.models.layers import _init_dense, apply_rotary, init_norm, linear, rms_norm

NEG_INF = -2.0 ** 30


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora: int = 768
    kv_lora: int = 256
    nope_dim: int = 64
    rope_dim: int = 32
    v_dim: int = 64
    # Decode-time weight absorption (DeepSeek-style): fold kv_up's key half
    # into the query and its value half into the output, so attention runs
    # directly against the compressed cache — O(S·kv_lora·H) per step
    # instead of O(S·kv_lora·H·(nope+v)) for re-expanding the cache.
    absorb: bool = False


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(key, cfg) -> Dict[str, Any]:
    d = cfg.d_model
    hd = cfg.head_dim
    keys = jax.random.split(key, 8)
    if cfg.mla is not None:
        m = cfg.mla
        qk_dim = m.nope_dim + m.rope_dim
        p = {
            "q_down": _init_dense(keys[0], d, m.q_lora),
            "q_norm": init_norm(m.q_lora),
            "q_up": _init_dense(keys[1], m.q_lora, cfg.n_heads * qk_dim),
            "kv_down": _init_dense(keys[2], d, m.kv_lora + m.rope_dim),
            "kv_norm": init_norm(m.kv_lora),
            "kv_up": _init_dense(keys[3], m.kv_lora,
                                 cfg.n_heads * (m.nope_dim + m.v_dim)),
            "wo": _init_dense(keys[4], cfg.n_heads * m.v_dim, d),
        }
        return p
    p = {
        "wq": _init_dense(keys[0], d, cfg.n_heads * hd),
        "wk": _init_dense(keys[1], d, cfg.n_kv_heads * hd),
        "wv": _init_dense(keys[2], d, cfg.n_kv_heads * hd),
        "wo": _init_dense(keys[3], cfg.n_heads * hd, d),
    }
    if cfg.qk_norm:
        p["q_ln"] = init_norm(hd)
        p["k_ln"] = init_norm(hd)
    return p


def init_cross_attention(key, cfg) -> Dict[str, Any]:
    d = cfg.d_model
    hd = cfg.head_dim
    keys = jax.random.split(key, 4)
    return {
        "wq": _init_dense(keys[0], d, cfg.n_heads * hd),
        "wk": _init_dense(keys[1], d, cfg.n_heads * hd),
        "wv": _init_dense(keys[2], d, cfg.n_heads * hd),
        "wo": _init_dense(keys[3], cfg.n_heads * hd, d),
    }


# ---------------------------------------------------------------------------
# KV cache (optionally int8)
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16, quantize: bool = False,
                  window: Optional[int] = None) -> Dict[str, Any]:
    slots = min(max_len, window) if window else max_len
    shape = (batch, slots, n_kv, head_dim)
    if quantize:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3], jnp.float32),
                "v_scale": jnp.zeros(shape[:3], jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _quant_tok(x):
    """(..., KV, D) → int8 codes + per-(..., KV) scale.

    Per-(token, head) absmax scales with a 1e-6 floor, so all-zero rows
    quantize to exact zeros instead of 0/0 NaNs. Codes are clipped to
    [-127, 127] before the int8 cast: ``round(amax / scale)`` can land on
    128.0 under fp rounding, which would wrap to -128 — flipping the
    row's largest-magnitude element to the wrong sign. Pure elementwise +
    one reduction over the trailing axis, so it vmaps/jits over any
    leading shape (both serving backends share this one quantizer)."""
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    codes = jnp.clip(jnp.round(x / scale[..., None]),
                     -127.0, 127.0).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def _cache_write(cache, k, v, pos, window):
    """Write new k/v (B, S, KV, D) at absolute position ``pos``."""
    slots = cache["k"].shape[1]
    s = k.shape[1]
    if window and s >= slots:
        # ring buffer: keep the last ``slots`` tokens, each at slot p%slots
        shift = (pos + s) % slots
        k = jnp.roll(k[:, -slots:], shift, axis=1)
        v = jnp.roll(v[:, -slots:], shift, axis=1)
        idx = 0
    else:
        idx = (pos % slots) if window else pos
    quant = "k_scale" in cache
    if quant:
        kq, ks = _quant_tok(k)
        vq, vs = _quant_tok(v)
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, idx, 1)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, idx, 1)
        cache["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k_scale"], ks, idx, 1)
        cache["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v_scale"], vs, idx, 1)
        return cache
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), idx, 1)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), idx, 1)
    return cache


def _ring_scatter(cache, k, v, pos):
    """Scatter-write a chunk of ``s`` tokens into a ring-buffer cache at
    slots ``(pos + i) % slots`` (``pos`` may be traced). Unlike
    ``_cache_write``'s contiguous ``dynamic_update_slice`` (which clamps at
    the cache edge instead of wrapping), this handles a chunk that straddles
    the ring boundary."""
    slots = cache["k"].shape[1]
    if k.shape[1] >= slots:
        # chunk wider than the ring: only the newest ``slots`` tokens
        # survive; dropping the rest keeps ``idx`` duplicate-free
        # (scatter-set order is unspecified under duplicates)
        off = k.shape[1] - slots
        k, v, pos = k[:, off:], v[:, off:], pos + off
    idx = (pos + jnp.arange(k.shape[1])) % slots
    cache = dict(cache)
    if "k_scale" in cache:
        kq, ks = _quant_tok(k)
        vq, vs = _quant_tok(v)
        cache["k"] = cache["k"].at[:, idx].set(kq)
        cache["v"] = cache["v"].at[:, idx].set(vq)
        cache["k_scale"] = cache["k_scale"].at[:, idx].set(ks)
        cache["v_scale"] = cache["v_scale"].at[:, idx].set(vs)
        return cache
    cache["k"] = cache["k"].at[:, idx].set(k.astype(cache["k"].dtype))
    cache["v"] = cache["v"].at[:, idx].set(v.astype(cache["v"].dtype))
    return cache


def _cache_read(cache) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if "k_scale" in cache:
        k = cache["k"].astype(jnp.float32) * cache["k_scale"][..., None]
        v = cache["v"].astype(jnp.float32) * cache["v_scale"][..., None]
        return k, v
    return cache["k"], cache["v"]


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------

def _grouped_attention(q, k, v, mask, softmax_scale) -> jnp.ndarray:
    """q: (B,S,H,D), k/v: (B,T,KV,Dv); H = KV * rep. mask: (S,T) or
    (B,1,1,S,T) additive. Used for decode (S small): no KV repeat."""
    b, s, h, dq = q.shape
    t, kv = k.shape[1], k.shape[2]
    rep = h // kv
    qg = q.reshape(b, s, kv, rep, dq)
    scores = jnp.einsum("bskrd,btkd->bkrst", qg, k) * softmax_scale
    scores = scores.astype(jnp.float32)
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None, None]
        scores = scores + mask
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrst,btkd->bskrd", p, v)
    return out.reshape(b, s, h, v.shape[-1])


Q_CHUNK = 1024   # query-block size bounding the (B,H,Cq,T) score tensor


def _chunked_attention(q, k, v, *, scale, causal: bool,
                       window: Optional[int] = None,
                       q_chunk: int = Q_CHUNK,
                       unroll: bool = False, row0=0) -> jnp.ndarray:
    """Train/prefill attention: KV repeated to H heads (so scores shard over
    the TP axis) and queries processed in blocks — the (B, H, Cq, T) block,
    not (B, H, S, T), bounds the working set. Softmax sees the full key axis
    per row, so this is exact (no online-softmax merge needed).

    q: (B,S,H,D); k/v: (B,T,KV,Dv) — repeated internally when KV < H.
    ``row0`` offsets the queries' absolute positions (may be traced): chunked
    prefill passes the cache clock so a partial-prompt chunk masks against
    absolute positions while attending over the whole cache.
    """
    b, s, h, dq = q.shape
    t, kv = k.shape[1], k.shape[2]
    if kv != h:
        rep = h // kv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    def block(qc, roff):
        scores = jnp.einsum("bshd,bthd->bhst", qc, k) * scale
        scores = shard_act(scores.astype(jnp.float32),
                           ("batch", "heads", None, None))
        if causal:
            rows = roff + jnp.arange(qc.shape[1])
            cols = jnp.arange(t)
            ok = cols[None, :] <= rows[:, None]
            if window is not None:
                ok &= cols[None, :] > rows[:, None] - window
            scores = jnp.where(ok[None, None], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bhst,bthd->bshd", p, v)

    if s <= q_chunk or s % q_chunk != 0:
        return block(q, row0)
    nc = s // q_chunk
    qr = q.reshape(b, nc, q_chunk, h, dq).swapaxes(0, 1)
    if unroll:
        outs = jnp.stack([block(qr[i], row0 + i * q_chunk)
                          for i in range(nc)])
    else:
        offs = row0 + jnp.arange(nc) * q_chunk

        def body(_, qc_off):
            qc, off = qc_off
            return 0, block(qc, off)

        _, outs = jax.lax.scan(body, 0, (qr, offs))
    return outs.swapaxes(0, 1).reshape(b, s, h, v.shape[-1])


def causal_mask(s: int, t: Optional[int] = None,
                window: Optional[int] = None) -> jnp.ndarray:
    t = t or s
    qi = jnp.arange(s)[:, None] + (t - s)     # absolute query positions
    ki = jnp.arange(t)[None, :]
    ok = ki <= qi
    if window is not None:
        ok &= ki > qi - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def attention(params, x, *, cfg, rope, mode: str = "train",
              cache: Optional[dict] = None, pos: Optional[jnp.ndarray] = None,
              block_tables: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Self-attention.

    mode: "train"/"prefill" (full sequence, causal (+window) mask, prefill
    also fills the cache), "decode" (single new token against the cache), or
    "chunk" (a partial-prefill continuation: ``s`` prompt tokens written at
    absolute position ``pos``, attending over the already-filled cache
    prefix — the same repeated-KV einsum as prefill, so the chunked path's
    activations match the monolithic prefill bit-for-bit). ``pos`` is a
    traced scalar, so the same trace serves the contiguous scheduler's
    shared clock AND the paged backend's per-slot positions (a chunked
    paged admission continues from its own prompt offset, shared-prefix
    gathers included) with no per-offset retrace.

    ``block_tables`` switches decode to the paged layout: the cache leaves
    are a block pool ``(num_blocks, block_size, KV, D)`` shared by all
    slots, ``pos`` is a per-slot length vector ``(B,)``, and each slot's
    K/V is reached through its ``block_tables`` row (no left-padding; see
    :mod:`repro.kernels.paged_attention`).
    """
    if cfg.mla is not None:
        if mode == "verify":
            raise NotImplementedError(
                "'verify' mode is not implemented for MLA attention")
        return _mla_attention(params, x, cfg=cfg, rope=rope, mode=mode,
                              cache=cache, pos=pos)
    if mode == "verify" and cfg.window:
        raise NotImplementedError(
            "'verify' mode is not implemented for sliding-window "
            "ring-buffer caches")
    b, s, d = x.shape
    hd = cfg.head_dim
    cos_t, sin_t = rope                      # (s, hd/2) for current tokens
    q = linear(params["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = linear(params["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
    v = linear(params["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(params["q_ln"], q)
        k = rms_norm(params["k_ln"], k)
    q = apply_rotary(q, cos_t, sin_t)
    k = apply_rotary(k, cos_t, sin_t)
    q = shard_act(q, ("batch", None, "heads", None))
    scale = hd ** -0.5

    if mode in ("train", "prefill"):
        out = _chunked_attention(q, k, v, scale=scale, causal=True,
                                 window=cfg.window,
                                 q_chunk=cfg.attn_q_chunk,
                                 unroll=cfg.unroll_chunks)
        if mode == "prefill":
            cache = _cache_write(cache, k, v, 0, cfg.window)
    elif mode == "chunk" and cfg.window:
        # ring-buffer continuation: the chunk's queries attend over the ring
        # *as of chunk entry* (the trailing min(pos, slots) keys)
        # concatenated with the chunk's own keys — attention runs before the
        # ring write, because writing first would evict up to s-1 in-window
        # keys the chunk's earliest rows still need. Each ring slot's
        # absolute key position is reconstructed from the clock (the largest
        # position ≡ slot (mod slots) already written; negative → never
        # written this admission → masked), so stale rows from a reused side
        # cache contribute exact zeros. The key axis is a rotation of the
        # monolithic ordering → tokens agree up to float reassociation,
        # served under the "sliding_window" agreement budget.
        kc, vc = _cache_read(cache)
        kc = shard_act(kc, ("batch", "seq_shard", "kv_heads", None))
        vc = shard_act(vc, ("batch", "seq_shard", "kv_heads", None))
        slots = kc.shape[1]
        j = jnp.arange(slots)
        kpos_ring = j + ((pos - j - 1) // slots) * slots
        kpos = jnp.concatenate([kpos_ring, pos + jnp.arange(s)])
        rows = pos + jnp.arange(s)
        ok = ((kpos[None, :] >= 0) & (kpos[None, :] <= rows[:, None])
              & (kpos[None, :] > rows[:, None] - cfg.window))
        mask = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
        k_all = jnp.concatenate([kc.astype(q.dtype), k.astype(q.dtype)], 1)
        v_all = jnp.concatenate([vc.astype(q.dtype), v.astype(q.dtype)], 1)
        out = _grouped_attention(q, k_all, v_all, mask, scale)
        cache = _ring_scatter(cache, k, v, pos)
    elif mode == "chunk":
        # partial-prefill continuation: write this chunk at the clock, then
        # run the prefill einsum against the whole cache with the rows'
        # absolute positions masking the unwritten suffix (zeros → exp(-inf)
        # → exact zero contributions, so the result is bit-identical to the
        # monolithic prefill of the full sequence for chunk sizes >= 2)
        cache = _cache_write(cache, k, v, pos, None)
        kc, vc = _cache_read(cache)
        kc = shard_act(kc, ("batch", "seq_shard", "kv_heads", None))
        vc = shard_act(vc, ("batch", "seq_shard", "kv_heads", None))
        out = _chunked_attention(q, kc.astype(q.dtype), vc.astype(q.dtype),
                                 scale=scale, causal=True, window=None,
                                 q_chunk=cfg.attn_q_chunk,
                                 unroll=cfg.unroll_chunks, row0=pos)
    elif mode == "verify":  # paged multi-position verify: pos is (B,)
        # speculative decoding's verifier forward: slot b's S tokens are
        # written through the block table at absolute positions
        # pos[b]..pos[b]+S-1, then every row attends over its own
        # inclusive prefix via ONE flattened paged_attention call — row
        # (b, j) becomes batch row b*S+j with length pos[b]+j, the exact
        # (query, keys, mask) triple a lockstep decode step at that
        # position would see, which is what makes greedy verify tokens
        # bit-identical to verifier-only decode
        if block_tables is None:
            raise NotImplementedError(
                "verify mode requires the paged KV layout (block tables); "
                "the contiguous cache has one shared clock and cannot "
                "score per-slot multi-position runs")
        if "k_scale" in cache:
            raise NotImplementedError(
                "verify mode requires an fp KV pool (speculative "
                "acceptance is gated off quantize_kv)")
        from repro.kernels.paged_attention import paged_attention
        bs_blk = cache["k"].shape[1]
        idx = pos[:, None] + jnp.arange(s)               # (B, S) abs pos
        rows = jnp.arange(b)
        phys = block_tables[rows[:, None], idx // bs_blk]
        off = idx % bs_blk
        cache = dict(cache)
        cache["k"] = cache["k"].at[phys, off].set(k.astype(cache["k"].dtype))
        cache["v"] = cache["v"].at[phys, off].set(v.astype(cache["v"].dtype))
        out = paged_attention(
            q.reshape(b * s, cfg.n_heads, hd), cache["k"], cache["v"],
            jnp.repeat(block_tables, s, axis=0), idx.reshape(-1),
            scale=scale).reshape(b, s, cfg.n_heads, hd)
    elif block_tables is not None:  # paged decode: s == 1, pos is (B,)
        # write the new K/V row through the table (slot b's token lands in
        # physical block ``bt[b, pos//bs]`` at offset ``pos % bs``; retired
        # slots point at the trash block and are masked out by length),
        # then attend via the gather kernel — exact-zero contributions from
        # masked columns keep tokens bit-identical to the contiguous
        # oracle at equal effective context (nb * bs == max_len). With a
        # quantized pool the new row is quantized per-(token, head) before
        # the write (the same ``_quant_tok`` the contiguous cache uses) and
        # attention runs through the fused int8-dequant kernel; tokens are
        # then tolerance-equivalent, not bit-identical (see
        # repro.serving.equivalence).
        bs_blk = cache["k"].shape[1]
        rows = jnp.arange(b)
        phys = block_tables[rows, pos // bs_blk]
        off = pos % bs_blk
        cache = dict(cache)
        if "k_scale" in cache:
            from repro.kernels.paged_attention_quant import \
                paged_attention_quant
            kq, ks = _quant_tok(k)
            vq, vs = _quant_tok(v)
            cache["k"] = cache["k"].at[phys, off].set(kq[:, 0])
            cache["v"] = cache["v"].at[phys, off].set(vq[:, 0])
            cache["k_scale"] = cache["k_scale"].at[phys, off].set(ks[:, 0])
            cache["v_scale"] = cache["v_scale"].at[phys, off].set(vs[:, 0])
            out = paged_attention_quant(
                q[:, 0], cache["k"], cache["v"], cache["k_scale"],
                cache["v_scale"], block_tables, pos, scale=scale)[:, None]
        else:
            from repro.kernels.paged_attention import paged_attention
            cache["k"] = cache["k"].at[phys, off].set(
                k[:, 0].astype(cache["k"].dtype))
            cache["v"] = cache["v"].at[phys, off].set(
                v[:, 0].astype(cache["v"].dtype))
            out = paged_attention(q[:, 0], cache["k"], cache["v"],
                                  block_tables, pos, scale=scale)[:, None]
    elif pos.ndim == 1:  # decode, per-row positions on a contiguous cache
        # the speculative drafter's cache: contiguous (max_slots, max_len)
        # rows, but slots sit at their own absolute positions (paged slots
        # are not left-padded), so the write is a per-row scatter and each
        # row masks against its own position — the same per-row semantics
        # as paged decode, without the block indirection
        if cfg.window or "k_scale" in cache:
            raise NotImplementedError(
                "per-row decode positions are not implemented for "
                "sliding-window or quantized contiguous caches")
        rows = jnp.arange(b)
        cache = dict(cache)
        cache["k"] = cache["k"].at[rows, pos].set(
            k[:, 0].astype(cache["k"].dtype))
        cache["v"] = cache["v"].at[rows, pos].set(
            v[:, 0].astype(cache["v"].dtype))
        kc, vc = _cache_read(cache)
        si = jnp.arange(kc.shape[1])
        valid = si[None, :] <= pos[:, None]               # (B, T)
        mask = jnp.where(valid, 0.0, NEG_INF)[:, None, None, None, :]
        out = _grouped_attention(q, kc.astype(q.dtype), vc.astype(q.dtype),
                                 mask, scale)
    else:  # decode: s == 1, absolute position ``pos``
        cache = _cache_write(cache, k, v, pos, cfg.window)
        kc, vc = _cache_read(cache)
        kc = shard_act(kc, ("batch", "seq_shard", "kv_heads", None))
        vc = shard_act(vc, ("batch", "seq_shard", "kv_heads", None))
        slots = kc.shape[1]
        si = jnp.arange(slots)
        if cfg.window:
            valid = (si <= (pos % slots)) | (pos >= slots)
        else:
            valid = si <= pos
        mask = jnp.where(valid, 0.0, NEG_INF)[None, None, None, None, :]
        out = _grouped_attention(q, kc.astype(q.dtype), vc.astype(q.dtype),
                                 mask, scale)
    out = out.reshape(b, s, cfg.n_heads * hd)
    return linear(params["wo"], out), cache


def _mla_attention(params, x, *, cfg, rope, mode, cache, pos):
    """Multi-head latent attention with compressed KV cache."""
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    cos_t, sin_t = rope                      # (s, rope_dim/2)
    qk_dim = m.nope_dim + m.rope_dim

    q = linear(params["q_up"],
               rms_norm(params["q_norm"], linear(params["q_down"], x)))
    q = q.reshape(b, s, h, qk_dim)
    q_nope, q_rope = q[..., :m.nope_dim], q[..., m.nope_dim:]

    ckv_full = linear(params["kv_down"], x)            # (B,S,kv_lora+rope)
    c_kv, k_rope = ckv_full[..., :m.kv_lora], ckv_full[..., m.kv_lora:]
    k_rope = k_rope.reshape(b, s, 1, m.rope_dim)

    q_rope = apply_rotary(q_rope, cos_t, sin_t)
    k_rope = apply_rotary(k_rope, cos_t, sin_t)

    def expand_kv(c_kv_in, k_rope_in):
        t = c_kv_in.shape[1]
        kv = linear(params["kv_up"], rms_norm(params["kv_norm"], c_kv_in))
        kv = kv.reshape(b, t, h, m.nope_dim + m.v_dim)
        k_nope, v = kv[..., :m.nope_dim], kv[..., m.nope_dim:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope_in.astype(k_nope.dtype),
                                      (b, t, h, m.rope_dim))], axis=-1)
        return k, v

    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = qk_dim ** -0.5
    if mode in ("train", "prefill"):
        k, v = expand_kv(c_kv, k_rope)
        out = _chunked_attention(qfull, k, v, scale=scale, causal=True,
                                 window=cfg.window,
                                 q_chunk=cfg.attn_q_chunk,
                                 unroll=cfg.unroll_chunks)
        if mode == "prefill":
            cache = dict(cache)
            cache["c_kv"] = jax.lax.dynamic_update_slice_in_dim(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), 0, 1)
            cache["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), 0, 1)
    elif mode == "chunk":
        # partial-prefill continuation, mirroring the dense chunk path:
        # write the chunk's compressed rows at the clock, re-expand the
        # WHOLE cache, and mask the unwritten suffix by absolute position.
        # Unwritten rows are zeros → rms_norm(0) = 0 → their expanded K/V
        # are masked before softmax, so they contribute exact zeros; the
        # expansion itself is recomputed per chunk, which can reassociate
        # vs the monolithic prefill gemm — served under the "mla"
        # agreement budget (measured ≈ exact).
        cache = dict(cache)
        cache["c_kv"] = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), pos, 1)
        cache["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), pos, 1)
        ckv_all = shard_act(cache["c_kv"], ("batch", "seq_shard", None))
        k, v = expand_kv(ckv_all.astype(x.dtype),
                         cache["k_rope"].astype(x.dtype))
        out = _chunked_attention(qfull, k, v, scale=scale, causal=True,
                                 window=None, q_chunk=cfg.attn_q_chunk,
                                 unroll=cfg.unroll_chunks, row0=pos)
    else:
        cache = dict(cache)
        cache["c_kv"] = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), pos, 1)
        cache["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), pos, 1)
        ckv_all = shard_act(cache["c_kv"], ("batch", "seq_shard", None))
        krope_all = cache["k_rope"]
        t = ckv_all.shape[1]
        mask = jnp.where(jnp.arange(t) <= pos, 0.0,
                         NEG_INF)[None, None, None, None, :]
        if m.absorb:
            out = _mla_absorbed_decode(params, qfull, ckv_all.astype(x.dtype),
                                       krope_all.astype(x.dtype), mask,
                                       scale, m, h)
        else:
            k, v = expand_kv(ckv_all.astype(x.dtype),
                             krope_all.astype(x.dtype))
            out = _grouped_attention(qfull, k, v, mask, scale)
    out = out.reshape(b, s, h * m.v_dim)
    return linear(params["wo"], out), cache


def _mla_absorbed_decode(params, qfull, ckv_all, krope_all, mask, scale,
                         m: MLAConfig, h: int):
    """Weight-absorbed MLA decode: attend in the compressed kv_lora space.

    scores[h,s] = (W_uk[h]ᵀ q_nope[h]) · n(c_s)  +  q_rope[h] · k_rope_s
    out[h]      = W_uv[h] @ Σ_s p[h,s] · n(c_s)

    Per step this costs O(H·kv_lora·(nope+v)) for the two absorptions plus
    O(S·H·kv_lora) for attention — the O(S·H·(nope+v)·kv_lora) cache
    re-expansion of the naive path is gone.
    """
    from repro.models.layers import rms_norm as _rms
    b, s, _, _ = qfull.shape                       # s == 1 (decode)
    q_nope = qfull[..., :m.nope_dim]               # (B,1,H,nope)
    q_rope = qfull[..., m.nope_dim:]               # (B,1,H,rope)
    w_up = params["kv_up"]["w"]                    # (kv_lora, H*(nope+v))
    if hasattr(w_up, "dequantize"):
        w_up = w_up.dequantize(qfull.dtype).T
    w_up = w_up.reshape(m.kv_lora, h, m.nope_dim + m.v_dim)
    w_uk = w_up[..., :m.nope_dim]                  # (kv_lora, H, nope)
    w_uv = w_up[..., m.nope_dim:]                  # (kv_lora, H, v)
    ckv_n = _rms(params["kv_norm"], ckv_all)       # normalize once per step
    # absorb K-half into the query: q̃ = W_ukᵀ q_nope → (B,1,H,kv_lora)
    q_tilde = jnp.einsum("bshn,chn->bshc", q_nope, w_uk.astype(qfull.dtype))
    s_nope = jnp.einsum("bshc,btc->bhst", q_tilde, ckv_n.astype(qfull.dtype))
    s_rope = jnp.einsum("bshr,btor->bhst", q_rope,
                        krope_all.astype(qfull.dtype))
    scores = (s_nope + s_rope).astype(jnp.float32) * scale
    scores = scores + mask[:, :, 0]               # (B,H,1,T)
    p = jax.nn.softmax(scores, axis=-1).astype(qfull.dtype)
    attended = jnp.einsum("bhst,btc->bshc", p, ckv_n.astype(qfull.dtype))
    # absorb V-half into the output
    return jnp.einsum("bshc,chv->bshv", attended, w_uv.astype(qfull.dtype))


def init_mla_cache(batch: int, max_len: int, cfg, dtype=jnp.bfloat16):
    m = cfg.mla
    return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora), dtype),
            "k_rope": jnp.zeros((batch, max_len, 1, m.rope_dim), dtype)}


def cross_attention(params, x, enc_out, *, cfg,
                    enc_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Decoder cross-attention over encoder output (full MHA)."""
    b, s, d = x.shape
    hd = cfg.head_dim
    t = enc_out.shape[1]
    q = linear(params["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = linear(params["wk"], enc_out).reshape(b, t, cfg.n_heads, hd)
    v = linear(params["wv"], enc_out).reshape(b, t, cfg.n_heads, hd)
    if enc_mask is not None:
        mask = jnp.where(enc_mask, 0.0, NEG_INF)[:, None, None, None, :]
        return linear(params["wo"],
                      _grouped_attention(q, k, v, mask, hd ** -0.5)
                      .reshape(b, s, cfg.n_heads * hd))
    out = _chunked_attention(q, k, v, scale=hd ** -0.5, causal=False,
                             q_chunk=cfg.attn_q_chunk,
                             unroll=cfg.unroll_chunks)
    return linear(params["wo"], out.reshape(b, s, cfg.n_heads * hd))
