"""Primitive layers: norms, embeddings, rotary, quant-aware dense.

Params are plain nested dicts. Kernels are named ``w`` with shape (in, out)
(the quantization pipeline and sharding rules key off these conventions).
``linear`` transparently consumes a QuantizedTensor (SQuant serving format,
(out, in)-major) — dequant-on-the-fly via the Pallas kernel on TPU or the
jnp reference elsewhere.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.qtypes import QuantizedTensor


def _init_dense(key, d_in: int, d_out: int, dtype=jnp.float32,
                scale: Optional[float] = None):
    s = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return {"w": (jax.random.normal(key, (d_in, d_out), dtype) * s)}


def linear(params, x: jnp.ndarray, use_kernel: str = "auto") -> jnp.ndarray:
    """x @ W. Accepts three kernel formats:
    * ``{"w": (in, out) float}`` — dense;
    * ``{"w": QuantizedTensor}`` — single-host quantized (Pallas path);
    * ``{"w_q"/"w_q4", "w_scale"}`` — sharded quantized serving format
      (dequant-on-the-fly; GSPMD shards the int codes)."""
    if "w_q" in params or "w_q4" in params:
        from repro.quant.apply import dequant_kernel
        w = dequant_kernel(params, x.dtype)               # (out, in)
        return x @ w.T
    w = params["w"]
    if isinstance(w, QuantizedTensor):
        from repro.kernels import ops                     # lazy import
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        y = ops.dequant_matmul(x2, w, use_pallas=use_kernel)
        return y.reshape(*lead, -1)
    return x @ w.astype(x.dtype)


def rms_norm(params, x: jnp.ndarray, eps: float = 1e-6,
             plus_one: bool = False) -> jnp.ndarray:
    """RMSNorm. ``plus_one=True`` uses the Gemma (1+g) parameterization."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    g = params["gain"].astype(jnp.float32)
    g = 1.0 + g if plus_one else g
    return (xf * g).astype(dt)


def layer_norm(params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf * params["gain"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


def init_norm(d: int, kind: str = "rms", plus_one: bool = False):
    if kind == "rms":
        gain = jnp.zeros((d,), jnp.float32) if plus_one else \
            jnp.ones((d,), jnp.float32)
        return {"gain": gain}
    return {"gain": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def embed(params, tokens: jnp.ndarray) -> jnp.ndarray:
    return params["embedding"][tokens]


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32):
    return {"embedding": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def rotary_tables(head_dim: int, max_len: int, theta: float = 10000.0,
                  dtype=jnp.float32):
    """(cos, sin) tables of shape (max_len, head_dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    t = jnp.arange(max_len, dtype=jnp.float32)
    freqs = jnp.outer(t, inv)
    return jnp.cos(freqs).astype(dtype), jnp.sin(freqs).astype(dtype)


def apply_rotary(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray
                 ) -> jnp.ndarray:
    """x: (B, S, H, D); cos/sin: (S, D/2) shared across the batch, or
    (B, S, D/2) per-row (paged decode: each slot sits at its own absolute
    position). cos/sin cast to x.dtype so rotary never promotes bf16
    activations."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    if cos.ndim == 3:
        c = cos.astype(x.dtype)[:, :, None, :]
        s = sin.astype(x.dtype)[:, :, None, :]
    else:
        c = cos.astype(x.dtype)[None, :, None, :]
        s = sin.astype(x.dtype)[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 ignore_id: int = -1) -> jnp.ndarray:
    """Mean cross-entropy over non-ignored positions; fp32 internally."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, labels[..., None].clip(0), axis=-1)[..., 0]
    mask = (labels != ignore_id).astype(jnp.float32)
    nll = (lse - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)
