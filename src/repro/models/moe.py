"""Mixture-of-Experts: GShard/Switch-style top-k routing with capacity.

Dispatch/combine are dense einsums over a (tokens, experts, capacity) one-hot
tensor — the standard form GSPMD partitions into all-to-alls when experts are
sharded over the 'model' axis and tokens over 'data'/'pod' (EP).

Expert FFN compute is ``experts × capacity × d × ff`` with
``capacity = tokens·top_k·capacity_factor / experts`` — i.e. proportional to
*active* FLOPs (MODEL_FLOPS = 6·N_active·D), not total parameters.

Expert kernels are stacked (experts, in, out) tensors named ``w`` — the
quantization pipeline treats each expert's matrix independently (per-expert
per-channel SQuant scales).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act
from repro.models.layers import _init_dense


def init_moe(key, d_model: int, d_ff: int, n_experts: int,
             kind: str = "swiglu") -> Dict:
    ks = jax.random.split(key, 4)
    s = 1.0 / jnp.sqrt(d_model)

    def bank(k, din, dout, scl):
        return {"w": jax.random.normal(k, (n_experts, din, dout),
                                       jnp.float32) * scl}

    p = {"router": _init_dense(ks[0], d_model, n_experts, scale=0.02),
         "wi": bank(ks[1], d_model, d_ff, s),
         "wdown": bank(ks[3], d_ff, d_model, 1.0 / jnp.sqrt(d_ff))}
    if kind in ("swiglu", "geglu"):
        p["wg"] = bank(ks[2], d_model, d_ff, s)
    return p


def _expert_matmul(bank, x):
    """x: (E, C, din) @ bank (E, din, dout) → (E, C, dout)."""
    if "w_q" in bank or "w_q4" in bank:              # sharded quant format
        from repro.quant.apply import dequant_kernel
        wd = dequant_kernel(bank, x.dtype)           # (E, out, in)
        return jnp.einsum("ecd,efd->ecf", x, wd)
    w = bank["w"]
    if hasattr(w, "dequantize"):                     # QuantizedTensor
        e = x.shape[0]
        din = x.shape[-1]
        # pipeline stores (E*out, in); dequant → (E, out, in) → (E, in, out)
        wd = w.dequantize(x.dtype).reshape(e, -1, din)
        return jnp.einsum("ecd,efd->ecf", x, wd)
    return jnp.einsum("ecd,edf->ecf", x, w.astype(x.dtype))


TOKEN_CHUNK = 8192   # dispatch-tensor bound: (chunk, E, C·chunk/T) per block


def moe_ffn(params, x: jnp.ndarray, *, n_experts: int, top_k: int,
            kind: str = "swiglu", capacity_factor: float = 1.25,
            dropless: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output, aux_loss). x: (B, S, D).

    ``dropless=True`` sets capacity = tokens (no token ever dropped) — used
    at decode time where capacity competition would make incremental results
    diverge from teacher forcing. Train/prefill use the GShard capacity.

    Long sequences are processed in TOKEN_CHUNK blocks (scan): the dense
    (T, E, C) dispatch one-hots are quadratic-ish in T and reached
    129 GB/device at the 32k-prefill cells (found by the dry-run).
    Capacity competition becomes per-block — the standard microbatched-MoE
    behaviour of production serving stacks.
    """
    b, s, d = x.shape
    t = b * s
    if t > 2 * TOKEN_CHUNK and t % TOKEN_CHUNK == 0 and s % (
            t // TOKEN_CHUNK) == 0:
        nblk = t // TOKEN_CHUNK
        xs = x.reshape(b, nblk, s // nblk, d).swapaxes(0, 1)

        def blk(_, xb):
            y, aux = moe_ffn(params, xb, n_experts=n_experts, top_k=top_k,
                             kind=kind, capacity_factor=capacity_factor,
                             dropless=dropless)
            return 0, (y, aux)

        _, (ys, auxs) = jax.lax.scan(jax.checkpoint(blk), 0, xs)
        return ys.swapaxes(0, 1).reshape(b, s, d), jnp.mean(auxs)
    xt = x.reshape(t, d)
    from repro.models.layers import linear as _linear
    logits = _linear(params["router"], xt).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)          # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    if dropless:
        capacity = t
    else:
        # GShard capacity rounds UP: floor would truncate the whole
        # capacity_factor slack at small per-block token counts (e.g.
        # t=4, k=2, E=4, cf=1.25 → floor(2.5)=2 drops tokens that the
        # 1.25 factor exists to keep, making quantized-vs-dense logits
        # diverge discontinuously whenever a router prob moves a token
        # across the cutoff).
        capacity = max(1, math.ceil(t * top_k * capacity_factor / n_experts))
        capacity = min(capacity, t)

    # position of each (token, k) within its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, n_experts, dtype=jnp.int32)  # (T,K,E)
    flatoh = onehot.reshape(t * top_k, n_experts)
    pos = jnp.cumsum(flatoh, axis=0) * flatoh - 1                  # (T*K, E)
    pos = pos.reshape(t, top_k, n_experts)
    within = (pos * onehot).sum(-1)                                # (T, K)
    expert = gate_idx
    keep = (within < capacity) & (within >= 0)

    # dispatch (T, E, C) / combine (T, E, C) — accumulated over the K
    # routing slots to avoid materializing a (T, K, E, C) tensor (a 12 GB
    # blow-up for moonshot-sized cells; found by the dry-run).
    disp = jnp.zeros((t, n_experts, capacity), x.dtype)
    comb = jnp.zeros((t, n_experts, capacity), x.dtype)
    for kk in range(top_k):
        oh_e = jax.nn.one_hot(expert[:, kk], n_experts, dtype=x.dtype)
        oh_c = jax.nn.one_hot(jnp.where(keep[:, kk], within[:, kk],
                                        capacity), capacity + 1,
                              dtype=x.dtype)[..., :-1]
        d_k = oh_e[:, :, None] * oh_c[:, None, :] \
            * keep[:, kk, None, None].astype(x.dtype)
        disp = disp + d_k
        comb = comb + d_k * gate_vals[:, kk, None, None].astype(x.dtype)

    ein = jnp.einsum("tec,td->ecd", disp, xt)                      # (E, C, D)
    ein = shard_act(ein, ("experts", None, None))
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else \
            (lambda v: jax.nn.gelu(v, approximate=True))
        h = act(_expert_matmul(params["wg"], ein)) * \
            _expert_matmul(params["wi"], ein)
    else:
        h = jax.nn.relu(_expert_matmul(params["wi"], ein))
    h = shard_act(h, ("experts", None, "expert_ff"))
    out = _expert_matmul(params["wdown"], h)                       # (E, C, D)
    y = jnp.einsum("tec,ecd->td", comb, out).reshape(b, s, d)

    # load-balancing aux loss (Switch): E · Σ_e f_e · p_e
    density = (disp.sum(-1) > 0).astype(jnp.float32).mean(0)       # (E,)
    mean_prob = probs.mean(0)
    aux = n_experts * jnp.sum(density * mean_prob)
    return y, aux
