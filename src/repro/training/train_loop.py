"""Train step builder + fault-tolerant training loop.

``make_train_step`` supports:
* microbatch gradient accumulation (lax.scan) — how the 398B config fits
  v5e HBM (see DESIGN.md §5);
* optional int8 cross-pod gradient all-reduce with error feedback
  (``pod_compress=True``): the step is shard_map-ed over the 'pod' axis with
  'data'/'model' left to GSPMD (auto axes).

``Trainer`` owns the loop: checkpoint-every-N (async), restart-from-latest,
preemption handling (SIGTERM → checkpoint + clean exit), and a straggler
monitor hook.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import compat
from repro.training.grad_compression import (compress_local,
                                             ring_allreduce_i8, ring_pad,
                                             unflatten_grads)
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def _accumulate_grads(loss_fn, params, batch, microbatches: int):
    """Mean loss/grads over ``microbatches`` sequential slices of the batch."""
    if microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def resh(x):
        b = x.shape[0]
        return x.reshape(microbatches, b // microbatches, *x.shape[1:])

    mbatch = jax.tree_util.tree_map(resh, batch)
    zero_g = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mb):
        gsum, lsum = carry
        (loss, metrics), g = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb)
        gsum = jax.tree_util.tree_map(
            lambda a, b_: a + b_.astype(jnp.float32), gsum, g)
        return (gsum, lsum + loss), metrics

    (gsum, lsum), metrics = jax.lax.scan(body, (zero_g, 0.0), mbatch)
    grads = jax.tree_util.tree_map(lambda g: g / microbatches, gsum)
    metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
    return lsum / microbatches, metrics, grads


def make_train_step(model, opt_cfg: AdamWConfig, *, microbatches: int = 1,
                    pod_compress: bool = False, mesh=None,
                    donate: bool = True,
                    grad_reduce_dtype=None) -> Callable:
    """Returns train_step(params, opt_state, batch) → (params, opt, metrics).

    ``grad_reduce_dtype=jnp.bfloat16`` casts accumulated gradients before
    they leave the backward pass, halving the FSDP reduce-scatter wire bytes
    (the f32 accumulation across microbatches is unaffected; Adam moments
    stay f32)."""

    def loss_fn(p, b):
        return model.train_loss(p, b)

    def plain_step(params, opt_state, batch):
        loss, metrics, grads = _accumulate_grads(loss_fn, params, batch,
                                                 microbatches)
        if grad_reduce_dtype is not None:
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(grad_reduce_dtype), grads)
        params, opt_state, info = adamw_update(params, grads, opt_state,
                                               opt_cfg)
        metrics = dict(metrics)
        metrics.update(info)
        metrics["loss"] = loss
        return params, opt_state, metrics

    if not pod_compress:
        return plain_step

    if mesh is None or "pod" not in dict(mesh.shape) or \
            dict(mesh.shape)["pod"] < 2:
        return plain_step
    n_pods = dict(mesh.shape)["pod"]

    # Three stages (old-jax partial-auto shard_map cannot lower ppermute /
    # axis_index, so the ring cannot live inside the grad step — see
    # grad_compression module comment):
    #   1. manual-'pod' shard_map ('data'/'model' auto → GSPMD): pod-local
    #      grads + the local half of the compression (error feedback).
    #   2. fully-manual shard_map: int8 ring all-reduce of the flat payload.
    #   3. plain GSPMD: unflatten + AdamW update.

    def local_step(params, err, batch):
        # every pytree arrives pod-LOCAL: batch is this pod's slice; params
        # are replicated across pods; err is per-pod.
        loss, metrics, grads = _accumulate_grads(loss_fn, params, batch,
                                                 microbatches)
        flat, new_err = compress_local(grads, err)
        flat = ring_pad(flat, n_pods)
        metrics = dict(metrics)
        metrics["loss"] = loss
        # pmean all scalars so the replicated out_specs are well-defined
        metrics = jax.tree_util.tree_map(
            lambda m: jax.lax.pmean(m, "pod"), metrics)
        return flat[None], new_err, metrics

    def ring_step(flat):
        # flat: (1, L) pod-local slab of the stacked payload
        return ring_allreduce_i8(flat[0], "pod", n_pods)[None]

    rep = P()          # replicated over the manual 'pod' axis
    pod0 = P("pod")    # leading pod dim

    def specs_like(tree, spec):
        return jax.tree_util.tree_map(lambda _: spec, tree)

    def wrapped(params, opt_state, err, batch):
        f1 = compat.shard_map(
            local_step, mesh,
            in_specs=(specs_like(params, rep), specs_like(err, pod0),
                      specs_like(batch, pod0)),
            out_specs=(pod0, specs_like(err, pod0), rep),
            manual_axes={"pod"})   # data/model stay auto (GSPMD)
        flat, new_err, metrics = f1(params, err, batch)
        f2 = compat.shard_map(ring_step, mesh, in_specs=pod0,
                              out_specs=pod0)
        reduced = f2(flat)            # every pod row holds the full sum
        grads = unflatten_grads(reduced[0] / n_pods, params)
        params, opt_state, info = adamw_update(params, grads, opt_state,
                                               opt_cfg)
        metrics = dict(metrics)
        metrics.update(info)
        return params, opt_state, new_err, metrics

    return wrapped


def init_pod_error(params, n_pods: int):
    """Per-pod error-feedback buffers (leading pod dim)."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# fault-tolerant loop
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    async_checkpoint: bool = True
    log_every: int = 10
    straggler_factor: float = 3.0   # step > factor × EWMA ⇒ flag


class Trainer:
    """Checkpoint/restart training loop with preemption + straggler handling.

    Failure model: any step may die (process kill, preemption signal). On
    restart, ``run`` resumes from the newest complete checkpoint — the test
    suite kills a training subprocess mid-run and verifies continuation.
    """

    def __init__(self, model, opt_cfg: AdamWConfig, cfg: TrainerConfig,
                 train_step: Optional[Callable] = None, monitor=None):
        from repro.runtime.monitor import StepMonitor
        self.model = model
        self.opt_cfg = opt_cfg
        self.cfg = cfg
        self.train_step = train_step or jax.jit(
            make_train_step(model, opt_cfg))
        self.monitor = monitor or StepMonitor(cfg.straggler_factor)
        self._preempted = False

    def _install_signal_handler(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not in main thread (tests)

    def run(self, params, data_iter, opt_state=None,
            step_hook: Optional[Callable] = None) -> Tuple[Any, Any, Dict]:
        from repro.checkpoint.checkpointer import Checkpointer
        self._install_signal_handler()
        ckpt = Checkpointer(self.cfg.checkpoint_dir,
                            async_save=self.cfg.async_checkpoint)
        opt_state = opt_state if opt_state is not None else adamw_init(params)
        start_step = 0
        restored = ckpt.restore_latest()
        if restored is not None:
            params, opt_state, start_step = restored
            print(f"[trainer] resumed from step {start_step}")
        history = []
        for step in range(start_step, self.cfg.total_steps):
            batch = next(data_iter)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.train_step(params, opt_state,
                                                         batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            flag = self.monitor.record(dt)
            if flag:
                print(f"[trainer] straggler: step {step} took {dt*1e3:.0f}ms "
                      f"(ewma {self.monitor.ewma*1e3:.0f}ms)")
            loss = float(metrics["loss"])
            history.append(loss)
            if step % self.cfg.log_every == 0:
                print(f"[trainer] step {step} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms)")
            if step_hook is not None:
                step_hook(step, params, metrics)
            done = step + 1
            if done % self.cfg.checkpoint_every == 0 or self._preempted \
                    or done == self.cfg.total_steps:
                ckpt.save(done, params, opt_state)
            if self._preempted:
                print(f"[trainer] preempted at step {done}; "
                      "checkpoint committed, exiting")
                break
        ckpt.wait()
        return params, opt_state, {"history": history,
                                   "stragglers": self.monitor.flagged}
