"""Cross-pod gradient compression: int8 ring all-reduce with error feedback.

Why: at multi-pod scale the pod-to-pod links are the thin resource. A plain
DP all-reduce ships f32 (or bf16) gradients across pods every step. Here the
cross-pod leg is replaced by a manual ring all-reduce (reduce-scatter +
all-gather via ``lax.ppermute``) whose wire payload is **int8 codes + one f32
scale per block** — ≈4× fewer cross-pod bytes — while the in-pod reduction
stays in full precision via GSPMD. The quantization residual is carried in an
error-feedback buffer (added back before the next step's compression), which
keeps SGD convergence intact (Karimireddy et al., 2019).

Mechanics: the train step is ``shard_map``-ed over the 'pod' axis only, with
'data'/'model' left as *auto* axes (GSPMD partitions the pod-local step as
usual). Inside, each pod holds pod-local mean gradients; ``ring_allreduce_i8``
sums them across pods in R-1 ppermute hops of int8 payloads.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 2048  # error-feedback / scale block size (f32 overhead: 1/2048)


def _quant_block(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (nblocks, BLOCK) f32 → (codes int8, scale f32 (nblocks, 1))."""
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    return jnp.round(x / scale).astype(jnp.int8), scale


def _flatten_pad(tree: Any) -> Tuple[jnp.ndarray, Any, int]:
    leaves, tdef = jax.tree_util.tree_flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    n = flat.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, (tdef, [l.shape for l in leaves],
                  [l.dtype for l in leaves], n), pad


def _unflatten(flat: jnp.ndarray, meta) -> Any:
    tdef, shapes, dtypes, n = meta
    flat = flat[:n]
    out = []
    off = 0
    for shp, dt in zip(shapes, dtypes):
        sz = 1
        for s in shp:
            sz *= s
        out.append(flat[off:off + sz].reshape(shp).astype(dt))
        off += sz
    return jax.tree_util.tree_unflatten(tdef, out)


def ring_allreduce_i8(flat: jnp.ndarray, axis: str, axis_size: int
                      ) -> jnp.ndarray:
    """Sum ``flat`` (per-shard f32 vector, length divisible by
    axis_size*BLOCK) across ``axis`` with int8 wire payloads.

    Ring reduce-scatter (R-1 hops) + ring all-gather (R-1 hops); every hop
    re-quantizes its chunk (int8 + per-block f32 scales).
    """
    r = axis_size
    idx = jax.lax.axis_index(axis)
    chunks = flat.reshape(r, -1)                       # (R, C)
    perm = [(i, (i + 1) % r) for i in range(r)]

    def quant_chunk(c):
        codes, scale = _quant_block(c.reshape(-1, BLOCK))
        return codes, scale

    def dequant(codes, scale):
        return (codes.astype(jnp.float32).reshape(-1, BLOCK)
                * scale).reshape(-1)

    # ---- reduce-scatter: after R-1 hops, shard i holds the sum of chunk i
    acc = chunks
    for hop in range(r - 1):
        send_idx = (idx - hop) % r                # chunk being forwarded
        send = jnp.squeeze(
            jax.lax.dynamic_slice_in_dim(acc, send_idx, 1, 0), 0)
        codes, scale = quant_chunk(send)
        codes = jax.lax.ppermute(codes, axis, perm)
        scale = jax.lax.ppermute(scale, axis, perm)
        recv = dequant(codes, scale)
        recv_idx = (idx - hop - 1) % r
        upd = jnp.squeeze(
            jax.lax.dynamic_slice_in_dim(acc, recv_idx, 1, 0), 0) + recv
        acc = jax.lax.dynamic_update_slice_in_dim(acc, upd[None], recv_idx, 0)

    # ---- all-gather: quantize each reduced chunk ONCE and circulate the
    # codes verbatim so every shard reconstructs bit-identical values
    # (including the owner, which uses its own quantized image).
    own_idx = (idx + 1) % r
    own = jnp.squeeze(jax.lax.dynamic_slice_in_dim(acc, own_idx, 1, 0), 0)
    codes, scale = quant_chunk(own)
    out = jnp.zeros_like(chunks)
    out = jax.lax.dynamic_update_slice_in_dim(
        out, dequant(codes, scale)[None], own_idx, 0)
    cur_idx = own_idx
    for hop in range(r - 1):
        codes = jax.lax.ppermute(codes, axis, perm)
        scale = jax.lax.ppermute(scale, axis, perm)
        cur_idx = (cur_idx - 1) % r
        out = jax.lax.dynamic_update_slice_in_dim(
            out, dequant(codes, scale)[None], cur_idx, 0)
    return out.reshape(-1)


# ---------------------------------------------------------------------------
# Split form for the train step.
#
# Old-jax (0.4.x) partial-auto shard_map cannot lower ``lax.axis_index`` /
# ``lax.ppermute`` (PartitionId is unsupported under SPMD partitioning, and
# collective-permute trips a manual-subgroup check in the partitioner), so
# the train step cannot run the ring inside the manual-'pod' grad step whose
# 'data'/'model' axes stay auto. Instead: the *local* half (flatten, error
# feedback — pure per-pod ops) runs inside the grad shard_map, the ring runs
# in a second, fully-manual shard_map, and the unflatten + optimizer update
# happen outside in plain GSPMD. ``train_loop.make_train_step`` wires the
# three stages together.
# ---------------------------------------------------------------------------

def compress_local(grads: Any, error: Any) -> Tuple[jnp.ndarray, Any]:
    """Local half of the compressed all-reduce.

    Flattens grads+error (BLOCK-padded) and computes the next error-feedback
    buffer. No collectives — safe inside a partial-auto shard_map.
    Returns ``(flat, new_error_tree)``; the error tree is rebuilt with the
    *error's* own meta so its pod-local leaves keep their leading
    ``init_pod_error`` dim (shapes round-trip step to step — no retrace).
    """
    flat, _, _ = _flatten_pad(grads)
    eflat, emeta, _ = _flatten_pad(error)
    flat = flat + eflat
    codes, scale = _quant_block(flat.reshape(-1, BLOCK))
    deq = (codes.astype(jnp.float32) * scale).reshape(-1)
    return flat, _unflatten(flat - deq, emeta)


def ring_pad(flat: jnp.ndarray, axis_size: int) -> jnp.ndarray:
    """Zero-pad so the ring's chunks divide evenly across ``axis_size``."""
    pad = (-flat.shape[0]) % (axis_size * BLOCK)
    return jnp.pad(flat, (0, pad)) if pad else flat


def flat_meta(template: Any):
    """The ``_unflatten`` meta for a pytree of arrays/ShapeDtypeStructs —
    static, so the caller can rebuild the gradient tree *outside* the
    shard_map that produced the flat vector."""
    leaves, tdef = jax.tree_util.tree_flatten(template)
    shapes = [tuple(l.shape) for l in leaves]
    n = 0
    for shp in shapes:
        sz = 1
        for s in shp:
            sz *= s
        n += sz
    return (tdef, shapes, [l.dtype for l in leaves], n)


def unflatten_grads(flat: jnp.ndarray, template: Any) -> Any:
    """Rebuild a gradient pytree shaped like ``template`` from the reduced
    flat vector (inverse of the flatten in ``compress_local``)."""
    return _unflatten(flat, flat_meta(template))
