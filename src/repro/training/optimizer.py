"""In-house AdamW.

States (m, v) are fp32 regardless of param dtype (bf16 params train stably
with fp32 moments at this scale). States shard exactly like their parameters
(the FSDP rule in distributed/sharding.py applies to the whole train state
pytree), which is what makes the 398B config fit: params bf16 (2 B/param) +
m,v fp32 (8 B/param) fully sharded over all 512 chips.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def adamw_init(params: Any) -> Dict[str, Any]:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def adamw_update(params: Any, grads: Any, state: Dict[str, Any],
                 cfg: AdamWConfig) -> Tuple[Any, Dict[str, Any], Dict]:
    step = state["step"]
    lr = _schedule(cfg, step)
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale), grads)
    else:
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), grads)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if p.ndim >= 2:                      # decoupled WD on matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * u
        return newp.astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    outs = [upd(p, g, m, v) for p, g, m, v
            in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in outs])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}
