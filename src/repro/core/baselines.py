"""Data-free quantization baselines the paper compares against.

* ``rtn``              — rounding-to-nearest (== SQuant-E): the DFQ default.
* ``equalize_pair``    — DFQ cross-layer weight equalization (Nagel et al. '19).
* ``bias_correction``  — DFQ bias correction given E[x] (from BN stats or 0).
* ``synthesize_inputs``— ZeroQ-style statistic-matching input distillation
                         (needs back-prop: the "No BP ✗" column of Table 1).
* ``adaround``         — AdaRound (Nagel et al. '20) layer-wise learned
                         rounding; combined with ``synthesize_inputs`` it is
                         the "data-free AdaRound" baseline of Table 5.

All are container-scale but algorithmically faithful; see
``benchmarks/bench_accuracy.py`` for the comparison protocol.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quant.qtypes import QuantizedTensor, from_codes, qmax_for_bits
from repro.quant.scales import compute_scale


# ---------------------------------------------------------------------------
# Rounding-to-nearest
# ---------------------------------------------------------------------------

def rtn(w2d: jnp.ndarray, bits: int, scale: Optional[jnp.ndarray] = None,
        scale_method: str = "max") -> QuantizedTensor:
    """Per-channel symmetric rounding quantization of an (M, N) matrix."""
    qmax = qmax_for_bits(bits)
    if scale is None:
        scale = compute_scale(w2d, bits, scale_method)
    codes = jnp.clip(jnp.round(w2d / scale), -qmax, qmax)
    return from_codes(codes.astype(jnp.int8), scale, bits)


# ---------------------------------------------------------------------------
# DFQ: cross-layer equalization + bias correction
# ---------------------------------------------------------------------------

def equalize_pair(w1: jnp.ndarray, w2: jnp.ndarray,
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Cross-layer equalization for y = W2·f(W1·x), f positive-homogeneous.

    w1: (H, I) rows feed hidden units; w2: (O, H) columns consume them.
    Scales s_h = sqrt(r1_h / r2_h) equalize per-channel ranges:
    W1' = W1 / s, W2' = W2 * s (Nagel et al. 2019, Sec. 4.1).
    """
    r1 = jnp.max(jnp.abs(w1), axis=1)
    r2 = jnp.max(jnp.abs(w2), axis=0)
    s = jnp.sqrt(jnp.maximum(r1, 1e-12) / jnp.maximum(r2, 1e-12))
    s = jnp.clip(s, 1e-4, 1e4)
    return w1 / s[:, None], w2 * s[None, :], s


def bias_correction(w_fp: jnp.ndarray, w_q: jnp.ndarray,
                    mu_x: jnp.ndarray) -> jnp.ndarray:
    """Expected-output correction  b += −(W_q − W_fp)·E[x]  (DFQ Sec. 4.2)."""
    return -(w_q - w_fp) @ mu_x


# ---------------------------------------------------------------------------
# ZeroQ-style statistic-matching input synthesis (needs BP)
# ---------------------------------------------------------------------------

def synthesize_inputs(stat_fn: Callable[[jnp.ndarray], jnp.ndarray],
                      target_stats: jnp.ndarray, shape: Tuple[int, ...],
                      key: jax.Array, iters: int = 100, lr: float = 0.1
                      ) -> jnp.ndarray:
    """Distill synthetic inputs x so stat_fn(x) matches target statistics.

    ``stat_fn`` maps an input batch to a vector of network statistics (e.g.
    per-layer pre-activation mean/var — the BN-statistics analogue). Plain
    Adam on the input; this is the paper's "data-generative" DFQ family.
    """
    x = 0.5 * jax.random.normal(key, shape)

    def loss(xv):
        s = stat_fn(xv)
        return jnp.mean((s - target_stats) ** 2)

    grad = jax.jit(jax.grad(loss))
    m = jnp.zeros_like(x)
    v = jnp.zeros_like(x)
    for t in range(1, iters + 1):
        g = grad(x)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9 ** t)
        vh = v / (1 - 0.999 ** t)
        x = x - lr * mh / (jnp.sqrt(vh) + 1e-8)
    return x


# ---------------------------------------------------------------------------
# AdaRound (layer-wise learned rounding)
# ---------------------------------------------------------------------------

def _rect_sigmoid(alpha, zeta=1.1, gamma=-0.1):
    return jnp.clip(jax.nn.sigmoid(alpha) * (zeta - gamma) + gamma, 0.0, 1.0)


def adaround(w2d: jnp.ndarray, x: jnp.ndarray, bits: int,
             iters: int = 600, lr: float = 3e-2, beta_range=(20.0, 2.0),
             reg_weight: float = 0.01, warmup: float = 0.2,
             scale: Optional[jnp.ndarray] = None) -> QuantizedTensor:
    """AdaRound: learn up/down rounding to minimize output MSE on ``x``.

    w2d: (M, N); x: (S, N) calibration inputs (real or synthetic).
    Output-MSE objective ‖xWᵀ − xW̃ᵀ‖² + λ·f_reg per Nagel et al. 2020:
    the rectified-sigmoid relaxation starts at the soft (exact) weights, the
    annealed regularizer polarizes h to {0,1}, and the reconstruction term
    picks the better side for borderline elements. λ is normalized by the
    initial hard-rounding reconstruction error so the balance is
    scale-invariant. Whole loop is a single jitted lax.fori_loop.
    """
    qmax = qmax_for_bits(bits)
    if scale is None:
        scale = compute_scale(w2d, bits, "max")
    ws = w2d / scale
    floor = jnp.floor(ws)
    resid = ws - floor                      # in [0, 1)
    # init so that _rect_sigmoid(alpha) ≈ resid (paper's init)
    p = jnp.clip((resid + 0.1) / 1.2, 1e-4, 1 - 1e-4)
    alpha0 = jnp.log(p / (1 - p))
    y_ref = x @ w2d.T
    # normalize λ: hard-rounding reconstruction error sets the scale
    hard = jnp.clip(floor + (resid > 0.5), -qmax, qmax) * scale
    rec0 = jnp.mean((x @ hard.T - y_ref) ** 2)
    lam = reg_weight * jnp.maximum(rec0, 1e-12)

    def qw(alpha):
        h = _rect_sigmoid(alpha)
        return jnp.clip(floor + h, -qmax, qmax) * scale

    def loss(alpha, beta, reg_on):
        h = _rect_sigmoid(alpha)
        rec = jnp.mean((x @ qw(alpha).T - y_ref) ** 2)
        reg = jnp.mean(1 - jnp.abs(2 * h - 1) ** beta)
        return rec + reg_on * lam * reg

    grad = jax.grad(loss)
    b0, b1 = beta_range

    def body(t, carry):
        alpha, m, v = carry
        tt = t + 1
        frac = tt / iters
        beta = b0 + (b1 - b0) * frac
        reg_on = jnp.where(frac > warmup, 1.0, 0.0)
        g = grad(alpha, beta, reg_on)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9 ** tt)
        vh = v / (1 - 0.999 ** tt)
        return (alpha - lr * mh / (jnp.sqrt(vh) + 1e-8), m, v)

    alpha, _, _ = jax.lax.fori_loop(
        0, iters, body, (alpha0, jnp.zeros_like(alpha0),
                         jnp.zeros_like(alpha0)))
    h_final = (_rect_sigmoid(alpha) > 0.5).astype(jnp.float32)
    codes = jnp.clip(floor + h_final, -qmax, qmax)
    return from_codes(codes.astype(jnp.int8), scale, bits)
