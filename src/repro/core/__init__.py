"""SQuant core: the paper's contribution as a composable JAX module."""
from repro.core.squant import SQuantConfig, squant, squant_codes  # noqa: F401
from repro.core.pipeline import quantize_tree, QuantReport  # noqa: F401
from repro.core.dispatch import BACKENDS, resolve_backend  # noqa: F401
