"""Sequential NumPy reference of SQuant (Algorithms 1-4), with a flip log.

This is a deliberately literal, loop-based transcription of the paper's
pseudocode. It serves two purposes:

1. An independent oracle for the vectorized JAX implementation
   (`core/squant.py`) and the Pallas kernels — two implementations written
   from different viewpoints must agree bit-exactly on the integer codes.
2. The flip log (element, stage, and the running kernel/channel sums at flip
   time) feeds the approximation-precision analysis of Appendix A.3
   (`core/hessian.py`).

Tie-breaking matches the vectorized code: stable sort, lower index wins among
equal |perturbation|.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class FlipEvent:
    m: int                 # output channel
    flat_idx: int          # flat index within the row
    stage: str             # "K" | "C"
    sign: float            # sign of δ before the flip (mutation is -sign)
    delta_before: float    # element δ before flip
    kernel_sum_before: float
    row_sum_before: float


def _topk_desc_stable(scores: np.ndarray, k: int) -> np.ndarray:
    """Indices of the k largest scores; stable (lower index wins ties)."""
    order = np.argsort(-scores, kind="stable")
    return order[:k]


def squant_reference(w2d: np.ndarray, scale: np.ndarray, bits: int,
                     group_size: Optional[int], enable_k: bool = True,
                     enable_c: bool = True
                     ) -> Tuple[np.ndarray, np.ndarray, List[FlipEvent]]:
    """Returns (codes int8 (M,N), delta, flip_log)."""
    m_sz, n_sz = w2d.shape
    qmax = 2 ** (bits - 1) - 1
    ws = w2d.astype(np.float64) / scale.reshape(m_sz, 1).astype(np.float64)

    g = group_size if group_size is not None else n_sz
    pad = (-n_sz) % g
    if pad:
        ws = np.pad(ws, ((0, 0), (0, pad)))
    ng = ws.shape[1] // g

    # SQuant-E
    q = np.clip(np.round(ws), -qmax, qmax)
    delta = q - ws
    log: List[FlipEvent] = []

    def flip_ok(mm, idx):
        d = delta[mm, idx]
        tgt = q[mm, idx] - np.sign(d)
        return -qmax <= tgt <= qmax

    row_sum = delta.sum(axis=1)

    def do_flip(mm, idx, stage):
        d = delta[mm, idx]
        s = np.sign(d)
        grp = idx // g
        ks = delta[mm, grp * g:(grp + 1) * g].sum()
        log.append(FlipEvent(mm, int(idx), stage, float(s), float(d),
                             float(ks), float(row_sum[mm])))
        q[mm, idx] -= s
        delta[mm, idx] -= s
        row_sum[mm] -= s

    # SQuant-K (Algorithm 2 per kernel)
    if enable_k and group_size is not None:
        for m in range(m_sz):
            for n in range(ng):
                sl = slice(n * g, (n + 1) * g)
                p = delta[m, sl].copy()
                e = p.sum()
                p[e * p <= 0] = 0.0                      # disable wrong-sign
                for j in range(len(p)):
                    if p[j] != 0 and not flip_ok(m, n * g + j):
                        p[j] = 0.0
                k = int(np.round(abs(e)))
                k = min(k, int(np.count_nonzero(p)))
                for j in _topk_desc_stable(np.abs(p), k):
                    do_flip(m, n * g + j, "K")

    # SQuant-C
    if enable_c:
        if group_size is None or not enable_k:
            # whole row is one kernel: row-level SQuantFlip
            for m in range(m_sz):
                p = delta[m].copy()
                e = p.sum()
                p[e * p <= 0] = 0.0
                for j in range(len(p)):
                    if p[j] != 0 and not flip_ok(m, j):
                        p[j] = 0.0
                k = int(np.round(abs(e)))
                k = min(k, int(np.count_nonzero(p)))
                for j in _topk_desc_stable(np.abs(p), k):
                    do_flip(m, j, "C")
        else:
            # Algorithm 4 candidates + channel-level Algorithm 2
            for m in range(m_sz):
                cand_idx = np.full(ng, -1)
                cand_val = np.zeros(ng)
                for n in range(ng):
                    sl = slice(n * g, (n + 1) * g)
                    d = delta[m, sl]
                    e1 = d.sum()
                    s1 = np.sign(e1)
                    if s1 == 0:
                        match = d != 0
                    else:
                        match = d * s1 > 0
                    for j in range(g):
                        if match[j] and not flip_ok(m, n * g + j):
                            match[j] = False
                    if not match.any():
                        continue
                    sc = np.where(match, np.abs(d), -1.0)
                    j = int(np.argmax(sc))          # stable: first max
                    cand_idx[n] = n * g + j
                    cand_val[n] = d[j]
                e_row = delta[m].sum()
                elig = (cand_idx >= 0) & (cand_val * e_row > 0)
                k_c = int(np.round(abs(e_row)))
                k_c = min(k_c, int(elig.sum()))
                sc = np.where(elig, np.abs(cand_val), -1.0)
                for n in _topk_desc_stable(sc, k_c):
                    do_flip(m, int(cand_idx[n]), "C")

    q = q[:, :n_sz]
    delta = delta[:, :n_sz]
    return q.astype(np.int8), delta, log
