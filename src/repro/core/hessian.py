"""Hessian machinery: Eq. (5) decomposition (Algorithm 3), the precise
objective Eq. (6), and the approximation-precision (AP) analysis of
Appendix A.3.

Data enters ONLY here, and only to *validate* the data-free approximation —
exactly like the paper's appendix experiment. The quantizer itself
(`core/squant.py`) never sees activations.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.reference import squant_reference


# ---------------------------------------------------------------------------
# E[x xᵀ] and Algorithm 3 decomposition
# ---------------------------------------------------------------------------

def second_moment(x: np.ndarray) -> np.ndarray:
    """E[x xᵀ] from samples x of shape (num_samples, NK)."""
    x = np.asarray(x, np.float64)
    return x.T @ x / x.shape[0]


@dataclasses.dataclass
class HessianCoeffs:
    """Coefficients of the E+K+C decomposition for one layer.

    c: scalar (channel-wise), k: (N,) per kernel, e: (N, K) per element.
    All strictly positive by construction (Algorithm 3).
    """
    c: float
    k: np.ndarray
    e: np.ndarray

    @property
    def group_size(self) -> int:
        return self.e.shape[1]


def decompose(h: np.ndarray, group_size: int, eps: float = 0.1,
              eps_k: float = 0.1) -> HessianCoeffs:
    """Algorithm 3: E[xxᵀ] ≈ E + K + C with positive coefficients.

    ``h`` is (NK, NK); kernels are contiguous blocks of ``group_size``.
    """
    nk = h.shape[0]
    if nk % group_size != 0:
        raise ValueError(f"H dim {nk} not divisible by group {group_size}")
    n = nk // group_size
    habs = np.abs(h)
    c = float((1.0 - eps) * habs.min())
    c = max(c, 1e-12)
    k = np.zeros(n)
    e = np.zeros((n, group_size))
    for i in range(n):
        sl = slice(i * group_size, (i + 1) * group_size)
        blk = habs[sl, sl]
        k[i] = max((1.0 - eps_k) * (blk.min() - c), 1e-12)
        e[i] = np.maximum(np.diag(blk) - c - k[i], 1e-12)
    return HessianCoeffs(c=c, k=k, e=e)


def reconstruction(co: HessianCoeffs) -> np.ndarray:
    """E + K + C as a dense (NK, NK) matrix."""
    n, g = co.e.shape
    nk = n * g
    out = np.full((nk, nk), co.c)
    for i in range(n):
        sl = slice(i * g, (i + 1) * g)
        out[sl, sl] += co.k[i]
    out[np.diag_indices(nk)] += co.e.reshape(-1)
    return out


# ---------------------------------------------------------------------------
# Objectives
# ---------------------------------------------------------------------------

def precise_objective(delta_row: np.ndarray, co: HessianCoeffs) -> float:
    """Eq. (6): Σ e_ni δ² + Σ_n k_n (Σ_i δ)² + c (Σ δ)² for one channel."""
    n, g = co.e.shape
    d = delta_row.reshape(n, g)
    t1 = float(np.sum(co.e * d * d))
    ks = d.sum(axis=1)
    t2 = float(np.sum(co.k * ks * ks))
    t3 = co.c * float(d.sum()) ** 2
    return t1 + t2 + t3


def exact_objective(delta_row: np.ndarray, h: np.ndarray) -> float:
    """Eq. (4): δ H δᵀ with the measured E[xxᵀ]."""
    return float(delta_row @ h @ delta_row)


def approx_objective(delta_row: np.ndarray, group_size: int) -> float:
    """Eq. (8): coefficients dropped (the data-free objective)."""
    d = delta_row.reshape(-1, group_size)
    return (float(np.sum(d * d)) + float(np.sum(d.sum(1) ** 2))
            + float(d.sum()) ** 2)


# ---------------------------------------------------------------------------
# Approximation precision (Appendix A.3, Table 6)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class APReport:
    flipped: int
    correct: int          # flips the precise objective Eq. (6) also prefers
    correct_exact: int    # flips the EXACT Eq. (4) objective δE[xxᵀ]δᵀ prefers
    correct_inorder: int  # running-order Δ Eq.(6) < 0 (secondary diagnostic)
    by_stage: dict

    @property
    def ap(self) -> float:
        return self.correct / max(self.flipped, 1)

    @property
    def ap_exact(self) -> float:
        return self.correct_exact / max(self.flipped, 1)

    @property
    def ap_inorder(self) -> float:
        return self.correct_inorder / max(self.flipped, 1)


def approximation_precision(w2d: np.ndarray, x_samples: np.ndarray,
                            bits: int, group_size: int,
                            scale: Optional[np.ndarray] = None,
                            enable_c: bool = True) -> APReport:
    """Run SQuant on ``w2d``; score every flip against Eq. (6) whose
    coefficients come from real activation samples (Algorithm 3 on the
    measured E[xxᵀ]).

    Table 6's "same optimization direction as the precise objective" is
    evaluated coordinate-wise at the final solution: a flip is *correct* if
    Eq. (6), as a function of that element's grid point with every other
    element held at the SQuant solution, prefers the flipped point over the
    rounded one. The running-order Δ variant is reported as a secondary
    diagnostic (it penalizes flips whose benefit is realized only after later
    flips rebalance the kernel/channel sums).
    """
    m_sz, n_sz = w2d.shape
    qmax = 2 ** (bits - 1) - 1
    if scale is None:
        scale = np.maximum(np.abs(w2d).max(axis=1, keepdims=True), 1e-12) / qmax
    h = second_moment(x_samples)
    co = decompose(h, group_size)
    g = group_size
    codes, delta, log = squant_reference(w2d, scale, bits, group_size,
                                         enable_k=True, enable_c=enable_c)
    ws = w2d.astype(np.float64) / scale.reshape(m_sz, 1)
    q0 = np.clip(np.round(ws), -qmax, qmax)
    mu = codes.astype(np.float64) - q0               # ±1 at flipped elements

    dg = delta.reshape(m_sz, -1, g)
    e_n = dg.sum(-1)                                 # (M, NG) final sums
    e_row = delta.sum(-1)                            # (M,)
    mug = mu.reshape(m_sz, -1, g)
    ecoef = np.broadcast_to(co.e[None], dg.shape)
    kcoef = np.broadcast_to(co.k[None, :, None], dg.shape)
    # f(final) - f(unflipped): negative → the precise objective keeps the flip
    diff = (ecoef * (dg ** 2 - (dg - mug) ** 2)
            + kcoef * (e_n[..., None] ** 2 - (e_n[..., None] - mug) ** 2)
            + co.c * (e_row[:, None, None] ** 2
                      - (e_row[:, None, None] - mug) ** 2))
    flips = mug != 0
    correct = int(np.sum((diff <= 1e-12) & flips))

    # exact objective Eq. (4): f(δ) − f(δ − μ e_j) = 2μ(Hδ)_j − μ² H_jj
    hd = delta @ h                                    # (M, NK)
    diff_exact = (2.0 * mu * hd - (mu ** 2) * np.diag(h)[None, :])
    correct_exact = int(np.sum((diff_exact <= 1e-12) & (mu != 0)))

    # secondary: in-order Δ from the flip log
    correct_inorder = 0
    by_stage: dict = {}
    for ev in log:
        n, i = ev.flat_idx // g, ev.flat_idx % g
        s = ev.sign
        dp = (co.e[n, i] * (1 - 2 * s * ev.delta_before)
              + co.k[n] * (1 - 2 * s * ev.kernel_sum_before)
              + co.c * (1 - 2 * s * ev.row_sum_before))
        st = by_stage.setdefault(ev.stage, [0, 0])
        st[0] += 1
        fin = diff[ev.m, n, i] <= 1e-12
        if fin:
            st[1] += 1
        if dp < 0:
            correct_inorder += 1
    return APReport(flipped=int(np.sum(flips)), correct=correct,
                    correct_exact=correct_exact,
                    correct_inorder=correct_inorder,
                    by_stage={k: tuple(v) for k, v in by_stage.items()})
