"""SQuant: on-the-fly data-free quantization via diagonal Hessian approximation.

Faithful JAX implementation of Algorithms 1-4 of the paper (Guo et al.,
ICLR 2022), fully vectorized over output channels and kernels/groups — no
Python loop touches a weight element, no autodiff, no data.

Terminology (paper → here)
--------------------------
* output channel  → row ``m`` of the 2-D weight view ``(M, N_flat)``
* kernel          → a contiguous *group* of ``G`` elements within a row.
  For conv weights ``(M, N, K)`` the natural grouping is G=K (paper exact).
  For FC/LLM matrices the paper sets K=1 and skips SQuant-K; we additionally
  support ``group_size=G`` so contiguous input groups play the kernel role
  (beyond-paper extension, see DESIGN.md §2). ``group_size=None`` reproduces
  the paper's FC path: SQuant-E followed by SQuant-C over the whole row.

Stages
------
SQuant-E  rounding: ``q0 = clip(round(w/s))``, element perturbation
          ``δ = q0 - w/s`` with |δ| ≤ 0.5 (r_e = 0.5).
SQuant-K  per group: flip ``k = ⌊|Σδ|⌉`` elements with sign(δ)=sign(Σδ),
          largest |δ| first (top-k; Appendix B.2) → |Σδ| ≤ 0.5 per group,
          |δ| < 1 per element (r_e relaxed to 1.0).
SQuant-C  per row over groups: each group exposes ONE candidate element
          (Algorithm 4) whose ±1 flip moves the group sum by −sign(candidate);
          flip the top-``⌊|Σ_groups Σδ|⌉`` candidates whose sign matches the
          row sum → |row Σδ| ≤ 0.5, per-group |Σδ| ≤ 1.0 (r_k relaxed to 1.0).

Algorithm 2/4 pseudocode inconsistency (the C-level ``e`` recomputed over the
candidate vector) is resolved per the Appendix-B proofs: the C level uses the
true row sum of post-K group sums. The candidate choice below is equivalent
to Algorithm 4's over-/under-SQuant branches — post-K, the candidate is the
max-|δ| element whose δ sign matches the post-K group sum (for over-SQuanted
groups that is the weakest flipped element, i.e. f_k; for under-SQuanted
groups the (k+1)-th strongest unflipped element).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quant.qtypes import QuantizedTensor, from_codes, qmax_for_bits
from repro.quant.scales import compute_scale


@dataclasses.dataclass(frozen=True)
class SQuantConfig:
    """Configuration for one SQuant invocation."""
    bits: int = 4
    group_size: Optional[int] = 128  # None → paper's FC path (E&C only)
    enable_k: bool = True            # SQuant-K (kernel/group-wise)
    enable_c: bool = True            # SQuant-C (output-channel-wise)
    scale_method: str = "max"        # "max" | "mse"

    def tag(self) -> str:
        lv = "E" + ("K" if self.enable_k else "") + ("C" if self.enable_c else "")
        return f"squant-{lv}-w{self.bits}g{self.group_size}"


# ---------------------------------------------------------------------------
# Core flip machinery (vectorized Algorithm 2)
# ---------------------------------------------------------------------------

def _ranks_desc(score: jnp.ndarray) -> jnp.ndarray:
    """Rank (0 = largest) of each element along the last axis.

    Double argsort; deterministic tie-break by index (argsort is stable).
    """
    order = jnp.argsort(-score, axis=-1)
    return jnp.argsort(order, axis=-1)


def _flip_once(q: jnp.ndarray, delta: jnp.ndarray, in_range: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One SQuantFlip (Algorithm 2) over the last axis.

    Args:
      q:      integer codes (float carrier), shape (..., L)
      delta:  perturbation q - w/s, shape (..., L)
      in_range: bool, True where a flip (q - sign(δ)) stays on the grid.

    Returns (q', delta', flip_mask). After the call the last-axis sum of
    delta' satisfies |Σδ'| ≤ 0.5 (up to clipping-induced eligibility loss).
    """
    e = jnp.sum(delta, axis=-1)                       # accumulated perturbation
    k = jnp.round(jnp.abs(e)).astype(jnp.int32)       # ⌊|e|⌉ flips
    # Eligible: same sign as e (strict — δ=0 never flips), flip stays on grid.
    eligible = (delta * e[..., None] > 0) & in_range
    k = jnp.minimum(k, jnp.sum(eligible, axis=-1))    # clip-safety clamp
    score = jnp.where(eligible, jnp.abs(delta), -1.0)
    flip = (_ranks_desc(score) < k[..., None]) & eligible
    sgn = jnp.sign(delta)
    q = q - jnp.where(flip, sgn, 0.0)
    delta = delta - jnp.where(flip, sgn, 0.0)
    return q, delta, flip


def _c_stage(q: jnp.ndarray, delta: jnp.ndarray, in_range: jnp.ndarray
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """SQuant-C over groups: (M, NG, G) → flip ≤1 candidate per group.

    Implements Algorithm 4 (perturbation update) + Algorithm 2 at the
    channel level, vectorized.
    """
    e1 = jnp.sum(delta, axis=-1)                      # (M, NG) post-K sums
    sgn1 = jnp.sign(e1)[..., None]
    # Candidate per group: max |δ| among elements whose δ sign matches the
    # post-K group sum (Algorithm 4 over/under branches collapse to this).
    # Groups with e1 == 0 admit any sign (Algorithm 4 line 10 with k=0).
    match = jnp.where(sgn1 == 0.0, delta != 0.0, delta * sgn1 > 0.0)
    cscore = jnp.where(match & in_range, jnp.abs(delta), -1.0)  # (M, NG, G)
    cand_idx = jnp.argmax(cscore, axis=-1)            # (M, NG)
    cand_val = jnp.take_along_axis(delta, cand_idx[..., None], axis=-1)[..., 0]
    has_cand = jnp.take_along_axis(cscore, cand_idx[..., None], axis=-1)[..., 0] > 0.0

    e_row = jnp.sum(e1, axis=-1)                      # (M,) channel ASE
    k_c = jnp.round(jnp.abs(e_row)).astype(jnp.int32)
    elig = has_cand & (cand_val * e_row[..., None] > 0.0)
    k_c = jnp.minimum(k_c, jnp.sum(elig, axis=-1))
    gscore = jnp.where(elig, jnp.abs(cand_val), -1.0)
    gflip = (_ranks_desc(gscore) < k_c[..., None]) & elig     # (M, NG)

    onehot = (jax.lax.broadcasted_iota(jnp.int32, delta.shape, 2)
              == cand_idx[..., None]) & gflip[..., None]
    step = jnp.where(onehot, jnp.sign(cand_val)[..., None], 0.0)
    return q - step, delta - step, gflip


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def _as_groups(w2d: jnp.ndarray, group_size: Optional[int]
               ) -> Tuple[jnp.ndarray, int]:
    """(M, N) → (M, NG, G) with zero padding; returns padded length."""
    m, n = w2d.shape
    g = group_size if group_size is not None else n
    pad = (-n) % g
    if pad:
        w2d = jnp.pad(w2d, ((0, 0), (0, pad)))
    return w2d.reshape(m, (n + pad) // g, g), pad


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "enable_k",
                                             "enable_c"))
def squant_codes(w2d: jnp.ndarray, scale: jnp.ndarray, *, bits: int,
                 group_size: Optional[int], enable_k: bool, enable_c: bool):
    """Run progressive SQuant; returns (codes int8 (M,N), delta, stats dict).

    ``delta`` is the final scaled perturbation q - w/s (analysis output).
    Padding elements (zeros) round to code 0 with δ=0 and are never eligible
    for flips, so they do not perturb group or channel sums.
    """
    m, n = w2d.shape
    qmax = qmax_for_bits(bits)
    ws = w2d.astype(jnp.float32) / scale.reshape(m, 1)
    wg, pad = _as_groups(ws, group_size)

    # --- SQuant-E: rounding -------------------------------------------------
    q = jnp.clip(jnp.round(wg), -qmax, qmax)
    delta = q - wg

    def in_range(qc, d):
        tgt = qc - jnp.sign(d)
        return (tgt >= -qmax) & (tgt <= qmax)

    flips_k = jnp.zeros((), jnp.int32)
    flips_c = jnp.zeros((), jnp.int32)
    # --- SQuant-K: per-group flips -------------------------------------
    if enable_k and (group_size is not None):
        q, delta, fk = _flip_once(q, delta, in_range(q, delta))
        flips_k = jnp.sum(fk).astype(jnp.int32)
    # --- SQuant-C: per-row flips over groups ---------------------------
    if enable_c:
        if group_size is None or not enable_k:
            # Paper FC path (K skipped, Sec. 3.4) and the E&C ablation: the
            # whole row is one "kernel" — a row-level SQuantFlip. H-C only
            # constrains the row sum, so flips may hit any element.
            mrow = q.shape[0]
            qf, df = q.reshape(mrow, -1), delta.reshape(mrow, -1)
            qf, df, fc = _flip_once(qf, df, in_range(qf, df))
            q, delta = qf.reshape(q.shape), df.reshape(delta.shape)
            flips_c = jnp.sum(fc).astype(jnp.int32)
        else:
            q, delta, fc = _c_stage(q, delta, in_range(q, delta))
            flips_c = jnp.sum(fc).astype(jnp.int32)

    q = q.reshape(m, n + pad)[:, :n]
    delta = delta.reshape(m, n + pad)[:, :n]
    stats = {
        "flips_k": flips_k,
        "flips_c": flips_c,
        "row_case": jnp.abs(jnp.sum(delta, axis=-1)),
        "max_abs_delta": jnp.max(jnp.abs(delta)),
    }
    return q.astype(jnp.int8), delta, stats


def squant(w: jnp.ndarray, cfg: SQuantConfig,
           scale: Optional[jnp.ndarray] = None
           ) -> Tuple[QuantizedTensor, dict]:
    """Quantize a weight tensor with SQuant.

    Accepts (M, N) FC weights or (M, N, K) conv-layout weights (kernels =
    trailing K). Returns (QuantizedTensor, stats).
    """
    shape = tuple(w.shape)
    if w.ndim == 3:                       # conv: groups are true kernels
        m, n, k = shape
        w2d = w.reshape(m, n * k)
        group_size = None if k == 1 else k
    elif w.ndim == 2:
        m, n = shape
        w2d = w
        group_size = cfg.group_size
        if group_size is not None and group_size >= n:
            group_size = None             # degenerate: one group == row
    else:
        raise ValueError(f"squant expects 2-D or 3-D weights, got {shape}")

    if scale is None:
        scale = compute_scale(w2d, cfg.bits, cfg.scale_method)
    codes, delta, stats = squant_codes(
        w2d, scale, bits=cfg.bits, group_size=group_size,
        enable_k=cfg.enable_k, enable_c=cfg.enable_c)
    qt = from_codes(codes.reshape(shape), scale, cfg.bits, group_size=None)
    stats = dict(stats)
    stats["group_size"] = group_size
    return qt, stats


def dequantize(qt: QuantizedTensor, dtype=jnp.float32) -> jnp.ndarray:
    return qt.dequantize(dtype)
