"""Backend dispatch for the batched quantization pipeline.

``quantize_tree`` groups same-(shape, dtype) leaves into buckets; this module
turns one stacked bucket ``(B, M, N)`` into int8 codes + per-row scales with a
fixed, small number of asynchronous dispatches — no host sync. The
``backend`` string is threaded down to ``kernels/ops.squant_flip_batched``:

* ``"ref"``        — vmapped jnp core (``core.squant.squant_codes``); the
                     production path on CPU.
* ``"pallas"``     — compiled Pallas TPU kernel, one launch per bucket (the
                     batch is flattened into rows — SQuant is row-independent,
                     so ``(B, M, N) → (B*M, N)`` is exact, not approximate).
* ``"interpret"``  — same kernel body executed by the Pallas interpreter
                     (CPU validation of the TPU path).
* ``"auto"``       — TPU→pallas, anything else→ref.

Scales are computed by ONE jitted function regardless of backend, so flip
decisions (which compare ``w/s`` against the integer grid) are bitwise
comparable across backends. RTN has no custom kernel (it is a pure
elementwise round); it runs as one jitted vmapped op regardless of backend.

The serial per-layer path in ``core.pipeline`` calls these same helpers with
``B=1``, which makes batched-vs-serial bit-exactness hold by construction
while the batched path still exercises the stack/vmap equivalence.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.quant.qtypes import qmax_for_bits
from repro.quant.scales import compute_scale

BACKENDS = ("auto", "ref", "pallas", "interpret")

_METHOD_FLAGS = {
    "squant":    (True, True),
    "squant_e":  (False, False),
    "squant_ek": (True, False),
    "squant_ec": (False, True),
}


def resolve_backend(backend: str) -> str:
    """Validate and resolve ``"auto"`` to a concrete backend."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; options {BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return backend


@functools.lru_cache(maxsize=None)
def _scales_fn(bits: int, scale_method: str):
    """jit(vmap(compute_scale)): the single scale source for all backends."""
    return jax.jit(jax.vmap(
        lambda w2d: compute_scale(w2d, bits, scale_method)))


@functools.lru_cache(maxsize=None)
def _rtn_fn(bits: int):
    qmax = qmax_for_bits(bits)

    def one(w2d, scale):
        return jnp.clip(jnp.round(w2d / scale), -qmax, qmax).astype(jnp.int8)
    return jax.jit(jax.vmap(one))


def quantize_codes_batched(ws: jnp.ndarray, *, method: str, bits: int,
                           group_size: Optional[int], scale_method: str = "max",
                           backend: str = "ref"
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize one stacked bucket.

    Args:
      ws: (B, M, N) stack of same-shape row-major weight matrices.
      group_size: effective kernel/group size for this bucket (None → whole
        row, the paper's FC path), already clamped by the caller.

    Returns ``(codes int8 (B, M, N), scales (B, M, 1))``. Everything is
    dispatched asynchronously; the caller owns the single end-of-pipeline
    sync.
    """
    scales = _scales_fn(bits, scale_method)(ws)
    if method == "rtn":
        codes = _rtn_fn(bits)(ws, scales)
    else:
        enable_k, enable_c = _METHOD_FLAGS[method]
        codes = ops.squant_flip_batched(
            ws, scales, bits=bits, group_size=group_size,
            enable_k=enable_k, enable_c=enable_c, use_pallas=backend)
    return codes, scales
