"""Backend dispatch for the batched quantization pipeline.

``quantize_tree`` groups same-(shape, dtype) leaves into buckets; this module
turns one stacked bucket ``(B, M, N)`` into int8 codes + per-row scales with a
fixed, small number of asynchronous dispatches — no host sync. The
``backend`` string is threaded down to ``kernels/ops.squant_flip_batched``:

* ``"ref"``        — vmapped jnp core (``core.squant.squant_codes``); the
                     production path on CPU.
* ``"pallas"``     — compiled Pallas TPU kernel, one launch per bucket (the
                     batch is flattened into rows — SQuant is row-independent,
                     so ``(B, M, N) → (B*M, N)`` is exact, not approximate).
* ``"interpret"``  — same kernel body executed by the Pallas interpreter
                     (CPU validation of the TPU path).
* ``"auto"``       — TPU→pallas, anything else→ref.

Scales are computed by ONE jitted function regardless of backend, so flip
decisions (which compare ``w/s`` against the integer grid) are bitwise
comparable across backends. RTN has no custom kernel (it is a pure
elementwise round); it runs as one jitted vmapped op regardless of backend.

The serial per-layer path in ``core.pipeline`` calls these same helpers with
``B=1``, which makes batched-vs-serial bit-exactness hold by construction
while the batched path still exercises the stack/vmap equivalence.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import compat
from repro.kernels import ops
from repro.quant.qtypes import qmax_for_bits
from repro.quant.scales import compute_scale

BACKENDS = ("auto", "ref", "pallas", "interpret")

_METHOD_FLAGS = {
    "squant":    (True, True),
    "squant_e":  (False, False),
    "squant_ek": (True, False),
    "squant_ec": (False, True),
}


def resolve_backend(backend: str) -> str:
    """Validate and resolve ``"auto"`` to a concrete backend."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; options {BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return backend


@functools.lru_cache(maxsize=None)
def _scales_fn(bits: int, scale_method: str):
    """jit(vmap(compute_scale)): the single scale source for all backends."""
    return jax.jit(jax.vmap(
        lambda w2d: compute_scale(w2d, bits, scale_method)))


@functools.lru_cache(maxsize=None)
def _rtn_fn(bits: int):
    qmax = qmax_for_bits(bits)

    def one(w2d, scale):
        return jnp.clip(jnp.round(w2d / scale), -qmax, qmax).astype(jnp.int8)
    return jax.jit(jax.vmap(one))


def quantize_codes_batched(ws: jnp.ndarray, *, method: str, bits: int,
                           group_size: Optional[int], scale_method: str = "max",
                           backend: str = "ref"
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize one stacked bucket.

    Args:
      ws: (B, M, N) stack of same-shape row-major weight matrices.
      group_size: effective kernel/group size for this bucket (None → whole
        row, the paper's FC path), already clamped by the caller.

    Returns ``(codes int8 (B, M, N), scales (B, M, 1))``. Everything is
    dispatched asynchronously; the caller owns the single end-of-pipeline
    sync.
    """
    scales = _scales_fn(bits, scale_method)(ws)
    if method == "rtn":
        codes = _rtn_fn(bits)(ws, scales)
    else:
        enable_k, enable_c = _METHOD_FLAGS[method]
        codes = ops.squant_flip_batched(
            ws, scales, bits=bits, group_size=group_size,
            enable_k=enable_k, enable_c=enable_c, use_pallas=backend)
    return codes, scales


# ---------------------------------------------------------------------------
# Sharded bucket dispatch (multi-device row partitioning)
# ---------------------------------------------------------------------------
# SQuant's flip objective is row-independent: every stage (E rounding, K
# group flips, C channel flips) and the scale computation operate within a
# single output-channel row. Partitioning the stacked bucket's B*M rows
# across a mesh axis is therefore EXACT — each device runs the same jitted
# helpers (`quantize_codes_batched` with B=1) on its row slab, so sharded
# codes/scales are bitwise identical to the unsharded batched path by
# construction.

@functools.lru_cache(maxsize=None)
def _sharded_fn(mesh, mesh_axis: str, method: str, bits: int,
                group_size: Optional[int], scale_method: str, backend: str):
    """jit(shard_map(...)) cached per (mesh, static config); shapes are
    handled by jit retracing."""

    def slab(local):                      # local: (rows/ndev, N) row slab
        codes, scales = quantize_codes_batched(
            local[None], method=method, bits=bits, group_size=group_size,
            scale_method=scale_method, backend=backend)
        return codes[0], scales[0]

    spec = P(mesh_axis, None)
    return jax.jit(compat.shard_map(
        slab, mesh, in_specs=spec, out_specs=(spec, spec),
        manual_axes={mesh_axis}))


def quantize_codes_sharded(ws: jnp.ndarray, *, method: str, bits: int,
                           group_size: Optional[int],
                           scale_method: str = "max", backend: str = "ref",
                           mesh, mesh_axis: str = "data"
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize one stacked bucket with its rows partitioned over
    ``mesh_axis`` of ``mesh``.

    The (B, M, N) stack is flattened to (B*M, N) rows, zero-padded so the
    axis size divides the row count, and dispatched under ``shard_map`` —
    each device quantizes its own slab with the same backend helpers the
    single-device path uses. Padding rows quantize to code 0 and are sliced
    off before the un-flatten. Results are bit-identical to
    :func:`quantize_codes_batched`.
    """
    sizes = dict(mesh.shape)
    if mesh_axis not in sizes:
        raise ValueError(f"mesh has no {mesh_axis!r} axis; axes: "
                         f"{tuple(sizes)}")
    b, m, n = ws.shape
    ndev = int(sizes[mesh_axis])
    rows = b * m
    # shard_rows is the single owner of the partition scheme: the pad here
    # and the QuantReport accounting both derive from it.
    pad = sum(p for _, p in shard_rows(rows, ndev))
    flat = ws.reshape(rows, n)
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    codes, scales = _sharded_fn(mesh, mesh_axis, method, bits, group_size,
                                scale_method, backend)(flat)
    return (codes[:rows].reshape(b, m, n),
            scales[:rows].reshape(b, m, 1))


def shard_rows(total_rows: int, ndev: int):
    """Per-device (rows, pad_rows) for one sharded dispatch — the partition
    scheme ``quantize_codes_sharded`` implements (contiguous equal slabs,
    zero rows padding the tail devices)."""
    pad = (-total_rows) % ndev
    per = (total_rows + pad) // ndev
    out = []
    for d in range(ndev):
        real = max(0, min(per, total_rows - d * per))
        out.append((real, per - real))
    return out
