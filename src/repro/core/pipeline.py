"""Model-level on-the-fly quantization driver.

Walks a parameter pytree, quantizes every matmul weight with the requested
data-free method, and returns (new_tree, report). This is the "on-the-fly
framework" of Sec. 3.4: no data, no back-prop, per-layer wall time recorded
(Table 3's protocol).

Conventions (shared with ``repro.models``):
* dense kernels are dict leaves named ``w`` with shape (in, out);
* expert kernels are named ``w`` with shape (experts, in, out);
* conv kernels (test CNNs) are named ``w_conv`` with shape (KH, KW, in, out);
* 1-D vectors (norm gains, biases, lerp vectors) are never quantized.

SQuant semantics: rows are OUTPUT channels, so (in, out) kernels are
transposed to (out, in) before quantization. The stored QuantizedTensor keeps
the (out, in) layout — the serving layer (`models.layers.linear` /
`kernels.dequant_matmul`) consumes it directly.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import baselines
from repro.core.squant import SQuantConfig, squant
from repro.quant.qtypes import QuantizedTensor

METHODS = ("rtn", "squant", "squant_e", "squant_ek", "squant_ec")


def _method_cfg(method: str, bits: int, group_size: Optional[int],
                scale_method: str) -> SQuantConfig:
    table = {
        "squant":    dict(enable_k=True, enable_c=True),
        "squant_e":  dict(enable_k=False, enable_c=False),
        "squant_ek": dict(enable_k=True, enable_c=False),
        "squant_ec": dict(enable_k=False, enable_c=True),
    }
    return SQuantConfig(bits=bits, group_size=group_size,
                        scale_method=scale_method, **table[method])


def is_quantizable(path: Tuple[str, ...], leaf: Any) -> bool:
    if not isinstance(leaf, (jnp.ndarray, jax.Array)):
        return False
    if "router" in path:       # MoE routers: tiny + precision-sensitive
        return False
    name = path[-1] if path else ""
    if name == "w" and leaf.ndim in (2, 3):
        return True
    if name == "w_conv" and leaf.ndim == 4:
        return True
    return False


@dataclasses.dataclass
class LayerReport:
    path: str
    shape: Tuple[int, ...]
    millis: float
    method: str
    bits: int


@dataclasses.dataclass
class QuantReport:
    layers: List[LayerReport]
    total_millis: float
    method: str
    bits: int

    def summary(self) -> str:
        return (f"{self.method} w{self.bits}: {len(self.layers)} layers in "
                f"{self.total_millis:.1f} ms "
                f"({self.total_millis / max(len(self.layers), 1):.2f} ms/layer)")


def _quantize_leaf(leaf: jnp.ndarray, method: str, bits: int,
                   group_size: Optional[int], scale_method: str
                   ) -> QuantizedTensor:
    """Quantize one kernel; returns QuantizedTensor in (out, in)-major layout."""
    if leaf.ndim == 2:                       # (in, out) -> (out, in)
        w2d = leaf.T
    elif leaf.ndim == 3:                     # (E, in, out) -> (E*out, in)
        e, i, o = leaf.shape
        w2d = jnp.transpose(leaf, (0, 2, 1)).reshape(e * o, i)
    elif leaf.ndim == 4:                     # conv (KH,KW,in,out) -> (out,in,K)
        kh, kw, ci, co = leaf.shape
        w3d = jnp.transpose(leaf, (3, 2, 0, 1)).reshape(co, ci, kh * kw)
        if method == "rtn":
            return baselines.rtn(w3d.reshape(co, ci * kh * kw), bits,
                                 scale_method=scale_method)
        cfg = _method_cfg(method, bits, None, scale_method)
        qt, _ = squant(w3d, cfg)
        return qt
    else:
        raise ValueError(f"unsupported kernel rank {leaf.ndim}")

    if method == "rtn":
        return baselines.rtn(w2d, bits, scale_method=scale_method)
    cfg = _method_cfg(method, bits, group_size, scale_method)
    qt, _ = squant(w2d, cfg)
    return qt


def quantize_tree(params: Any, method: str = "squant", bits: int = 4,
                  group_size: Optional[int] = 128, scale_method: str = "max",
                  predicate: Optional[Callable] = None,
                  dequantize: bool = False) -> Tuple[Any, QuantReport]:
    """Quantize all matmul weights in a param tree.

    dequantize=True returns float weights (fake-quant — for accuracy evals on
    models whose forward pass expects dense arrays); otherwise leaves become
    QuantizedTensor (real serving format).
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; options {METHODS}")
    pred = predicate or is_quantizable
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out_leaves = []
    reports: List[LayerReport] = []
    t_total = 0.0
    for keypath, leaf in flat:
        path = tuple(getattr(k, "key", getattr(k, "idx", str(k)))
                     for k in keypath)
        path = tuple(str(p) for p in path)
        if not pred(path, leaf):
            out_leaves.append(leaf)
            continue
        t0 = time.perf_counter()
        qt = _quantize_leaf(leaf, method, bits, group_size, scale_method)
        jax.block_until_ready(qt.data)
        ms = (time.perf_counter() - t0) * 1e3
        t_total += ms
        reports.append(LayerReport("/".join(path), tuple(leaf.shape), ms,
                                   method, bits))
        if dequantize:
            wq = qt.dequantize(leaf.dtype)
            if leaf.ndim == 2:
                out_leaves.append(wq.T)
            elif leaf.ndim == 3:
                e, i, o = leaf.shape
                out_leaves.append(
                    jnp.transpose(wq.reshape(e, o, i), (0, 2, 1)))
            else:
                kh, kw, ci, co = leaf.shape
                w = wq.reshape(co, ci, kh, kw)
                out_leaves.append(jnp.transpose(w, (2, 3, 1, 0)))
        else:
            out_leaves.append(qt)
    tree = jax.tree_util.tree_unflatten(treedef, out_leaves)
    return tree, QuantReport(reports, t_total, method, bits)
