"""Model-level on-the-fly quantization driver.

Walks a parameter pytree, quantizes every matmul weight with the requested
data-free method, and returns (new_tree, report). This is the "on-the-fly
framework" of Sec. 3.4: no data, no back-prop, wall time recorded (Table 3's
protocol).

Execution modes:

* ``batched=True`` (default) — leaves are grouped into same-(2-D view shape,
  dtype, group) buckets; each bucket is stacked and quantized with ONE
  asynchronous dispatch (vmapped jnp core or a single flattened Pallas
  launch, see ``core.dispatch``), and the whole tree synchronizes with the
  device ONCE at the end. ``QuantReport`` carries the per-bucket wall times
  plus a dispatch/sync breakdown so Table-3-style numbers stay reportable.
* ``batched=True, mesh=...`` — same bucketing, but each bucket's rows are
  partitioned over the mesh's ``mesh_axis`` under ``shard_map``: every device
  quantizes its own output-channel slab (SQuant is row-independent, so the
  partition is exact — codes/scales are bitwise identical to the unsharded
  path). Output ``QuantizedTensor`` codes+scales inherit the source param's
  sharding rules (``distributed.sharding.quantized_tensor_shardings``), and
  the report gains a per-device shard breakdown.
* ``batched=False`` — the legacy per-layer reference path: one quantization
  call and one ``block_until_ready`` per leaf. Kept as the bit-exactness
  oracle and the serial baseline for ``benchmarks/bench_time.py``.

``backend`` selects the kernel implementation for the batched path
(``"auto" | "ref" | "pallas" | "interpret"``, see ``core.dispatch.BACKENDS``);
the serial path always uses the jnp reference.

Conventions (shared with ``repro.models``):
* dense kernels are dict leaves named ``w`` with shape (in, out);
* expert kernels are named ``w`` with shape (experts, in, out);
* conv kernels (test CNNs) are named ``w_conv`` with shape (KH, KW, in, out);
* 1-D vectors (norm gains, biases, lerp vectors) are never quantized.

SQuant semantics: rows are OUTPUT channels, so (in, out) kernels are
transposed to (out, in) before quantization. The stored QuantizedTensor keeps
the (out, in) layout — the serving layer (`models.layers.linear` /
`kernels.dequant_matmul`) consumes it directly.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.dispatch import (quantize_codes_batched,
                                 quantize_codes_sharded, resolve_backend,
                                 shard_rows)
from repro.quant.qtypes import (BucketReport, LayerReport, QuantReport,
                                ShardReport, from_codes)

METHODS = ("rtn", "squant", "squant_e", "squant_ek", "squant_ec")

# Module-level alias so tests can count device synchronizations: the batched
# path calls this exactly once per quantize_tree, the serial path once per
# quantized leaf.
_sync = jax.block_until_ready


def is_quantizable(path: Tuple[str, ...], leaf: Any) -> bool:
    if not isinstance(leaf, (jnp.ndarray, jax.Array)):
        return False
    if "router" in path:       # MoE routers: tiny + precision-sensitive
        return False
    name = path[-1] if path else ""
    if name == "w" and leaf.ndim in (2, 3):
        return True
    if name == "w_conv" and leaf.ndim == 4:
        return True
    return False


# ---------------------------------------------------------------------------
# Leaf planning: every quantizable leaf maps to a 2-D (out, in)-major view
# ---------------------------------------------------------------------------

def _plan_leaf(leaf: jnp.ndarray, method: str, group_size: Optional[int]
               ) -> Tuple[jnp.ndarray, Tuple[int, ...], Optional[int]]:
    """Return ``(w2d, qt_shape, eff_group)`` for one kernel leaf.

    ``eff_group`` mirrors the clamping in ``core.squant.squant`` exactly
    (group >= row length degenerates to the whole-row FC path; conv kernels
    use K=KH*KW as the natural group) so batched results are bit-identical to
    the per-layer path.
    """
    if leaf.ndim == 2:                       # (in, out) -> (out, in)
        w2d = leaf.T
        qt_shape = (leaf.shape[1], leaf.shape[0])
    elif leaf.ndim == 3:                     # (E, in, out) -> (E*out, in)
        e, i, o = leaf.shape
        w2d = jnp.transpose(leaf, (0, 2, 1)).reshape(e * o, i)
        qt_shape = (e * o, i)
    elif leaf.ndim == 4:                     # conv (KH,KW,in,out) -> (out, in*K)
        kh, kw, ci, co = leaf.shape
        k = kh * kw
        w2d = jnp.transpose(leaf, (3, 2, 0, 1)).reshape(co, ci * k)
        if method == "rtn":
            return w2d, (co, ci * k), None
        return w2d, (co, ci, k), (None if k == 1 else k)
    else:
        raise ValueError(f"unsupported kernel rank {leaf.ndim}")
    if method == "rtn":
        return w2d, qt_shape, None
    n = w2d.shape[1]
    eff = None if (group_size is None or group_size >= n) else group_size
    return w2d, qt_shape, eff


def _restore_dense(wq: jnp.ndarray, leaf_shape: Tuple[int, ...]
                   ) -> jnp.ndarray:
    """Fake-quant restore: (out, in)-major dequantized weights -> leaf layout."""
    if len(leaf_shape) == 2:
        return wq.T
    if len(leaf_shape) == 3:
        e, i, o = leaf_shape
        return jnp.transpose(wq.reshape(e, o, i), (0, 2, 1))
    kh, kw, ci, co = leaf_shape
    return jnp.transpose(wq.reshape(co, ci, kh, kw), (2, 3, 1, 0))


# ---------------------------------------------------------------------------
# Serial per-layer path (one dispatch + one device sync per leaf)
# ---------------------------------------------------------------------------

def _quantize_tree_serial(flat, treedef, pred, method, bits, group_size,
                          scale_method, dequantize):
    """Per-layer baseline: same dispatch helpers as the batched path, called
    with B=1 and synchronized after every leaf (the pre-batching protocol
    Table 3 timings were taken under)."""
    out_leaves = []
    reports: List[LayerReport] = []
    t_total = 0.0
    for keypath, leaf in flat:
        path = tuple(str(getattr(k, "key", getattr(k, "idx", str(k))))
                     for k in keypath)
        if not pred(path, leaf):
            out_leaves.append(leaf)
            continue
        t0 = time.perf_counter()
        w2d, qt_shape, eff = _plan_leaf(leaf, method, group_size)
        codes, scales = quantize_codes_batched(
            w2d[None], method=method, bits=bits, group_size=eff,
            scale_method=scale_method, backend="ref")
        qt = from_codes(codes[0].reshape(qt_shape), scales[0], bits)
        _sync(qt.data)
        ms = (time.perf_counter() - t0) * 1e3
        t_total += ms
        reports.append(LayerReport("/".join(path), tuple(leaf.shape), ms,
                                   method, bits))
        if dequantize:
            out_leaves.append(_restore_dense(qt.dequantize(leaf.dtype),
                                             tuple(leaf.shape)))
        else:
            out_leaves.append(qt)
    tree = jax.tree_util.tree_unflatten(treedef, out_leaves)
    return tree, QuantReport(reports, t_total, method, bits, backend="ref")


# ---------------------------------------------------------------------------
# Batched path: bucket -> stack -> one dispatch per bucket -> one sync total
# ---------------------------------------------------------------------------

# Cap on the transient stacked-bucket buffer: buckets whose stack would
# exceed this many bytes are dispatched in chunks, bounding peak memory at
# params + one chunk instead of params + the largest bucket. Still one device
# sync per tree.
_MAX_STACK_BYTES = 1 << 30


def _quantize_tree_batched(flat, treedef, pred, method, bits, group_size,
                           scale_method, dequantize, backend, mesh,
                           mesh_axis):
    ndev = int(dict(mesh.shape)[mesh_axis]) if mesh is not None else 1
    shard_acc = [[0, 0] for _ in range(ndev)]   # per-device [rows, pad_rows]

    t_begin = time.perf_counter()
    out_leaves: List[Any] = [None] * len(flat)
    # bucket key -> list of (leaf index, path, leaf, w2d, qt_shape)
    buckets: Dict[Tuple, List] = {}
    for idx, (keypath, leaf) in enumerate(flat):
        path = tuple(str(getattr(k, "key", getattr(k, "idx", str(k))))
                     for k in keypath)
        if not pred(path, leaf):
            out_leaves[idx] = leaf
            continue
        w2d, qt_shape, eff = _plan_leaf(leaf, method, group_size)
        key = (tuple(w2d.shape), str(w2d.dtype), eff)
        buckets.setdefault(key, []).append(
            (idx, path, leaf, w2d, qt_shape))

    layer_reports: List[LayerReport] = []
    bucket_reports: List[BucketReport] = []
    quantized: List[Any] = []                 # everything the final sync waits on
    n_q = sum(len(v) for v in buckets.values())
    for key, all_entries in buckets.items():
        (m, n), dtype, eff = key[0], key[1], key[2]
        layer_bytes = m * n * jnp.dtype(dtype).itemsize
        chunk = max(1, min(len(all_entries), _MAX_STACK_BYTES // layer_bytes))
        for c0 in range(0, len(all_entries), chunk):
            entries = all_entries[c0:c0 + chunk]
            tag = f"({m},{n})x{len(entries)} {dtype} g{eff}"
            tb0 = time.perf_counter()
            if len(entries) == 1:                        # singleton: no copy
                ws = entries[0][3][None]
            else:
                ws = jnp.stack([e[3] for e in entries])  # (B, M, N)
            if mesh is None:
                codes, scales = quantize_codes_batched(
                    ws, method=method, bits=bits, group_size=eff,
                    scale_method=scale_method, backend=backend)
            else:
                codes, scales = quantize_codes_sharded(
                    ws, method=method, bits=bits, group_size=eff,
                    scale_method=scale_method, backend=backend,
                    mesh=mesh, mesh_axis=mesh_axis)
                for d, (r, p) in enumerate(
                        shard_rows(len(entries) * m, ndev)):
                    shard_acc[d][0] += r
                    shard_acc[d][1] += p
            for bi, (idx, path, leaf, _, qt_shape) in enumerate(entries):
                qt = from_codes(codes[bi].reshape(qt_shape), scales[bi], bits)
                if dequantize:
                    out = _restore_dense(qt.dequantize(leaf.dtype),
                                         tuple(leaf.shape))
                elif mesh is not None:
                    # codes/scales inherit the source param's sharding rules
                    from repro.distributed.sharding import \
                        quantized_tensor_shardings
                    out = qt.with_placement(
                        *quantized_tensor_shardings(mesh, path, qt))
                else:
                    out = qt
                out_leaves[idx] = out
                quantized.append(out)
            bucket_ms = (time.perf_counter() - tb0) * 1e3
            bucket_reports.append(BucketReport(tag, len(entries), bucket_ms))
            for idx, path, leaf, _, _ in entries:
                layer_reports.append(LayerReport("/".join(path),
                                                 tuple(leaf.shape),
                                                 bucket_ms / len(entries),
                                                 method, bits, bucket=tag))
    dispatch_ms = (time.perf_counter() - t_begin) * 1e3

    t_sync0 = time.perf_counter()
    _sync(quantized)                          # the ONE device sync
    sync_ms = (time.perf_counter() - t_sync0) * 1e3
    # fold the sync into per-layer numbers so Σ layer.millis ≈ total
    for lr in layer_reports:
        lr.millis += sync_ms / max(n_q, 1)

    tree = jax.tree_util.tree_unflatten(treedef, out_leaves)
    total_ms = (time.perf_counter() - t_begin) * 1e3
    shards = [ShardReport(d, r, p) for d, (r, p) in enumerate(shard_acc)] \
        if mesh is not None else []
    return tree, QuantReport(layer_reports, total_ms, method, bits,
                             backend=backend, dispatch_millis=dispatch_ms,
                             sync_millis=sync_ms, buckets=bucket_reports,
                             mesh_axis=mesh_axis if mesh is not None else "",
                             mesh_size=ndev, shards=shards)


def quantize_tree(params: Any, method: str = "squant", bits: int = 4,
                  group_size: Optional[int] = 128, scale_method: str = "max",
                  predicate: Optional[Callable] = None,
                  dequantize: bool = False, backend: str = "auto",
                  batched: bool = True, mesh=None,
                  mesh_axis: str = "data") -> Tuple[Any, QuantReport]:
    """Quantize all matmul weights in a param tree.

    dequantize=True returns float weights (fake-quant — for accuracy evals on
    models whose forward pass expects dense arrays); otherwise leaves become
    QuantizedTensor (real serving format).

    backend: kernel implementation for the batched path — one of
    ``core.dispatch.BACKENDS`` (``"auto"`` resolves TPU→pallas, CPU→ref).
    batched=False falls back to the legacy per-layer loop (one dispatch and
    one device sync per leaf); it ignores ``backend`` and always runs the jnp
    reference.

    mesh: a ``jax.sharding.Mesh`` with a ``mesh_axis`` axis (see
    ``launch.mesh.make_quantize_mesh``) shards every bucket's rows across
    that axis under shard_map — exact (row-independent objective), results
    bitwise identical to ``mesh=None``. Sharded runs require ``batched=True``
    and report a per-device breakdown in ``QuantReport.shards``.
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; options {METHODS}")
    if mesh is not None and mesh_axis not in dict(mesh.shape):
        raise ValueError(f"mesh has no {mesh_axis!r} axis; axes: "
                         f"{tuple(dict(mesh.shape))}")
    backend = resolve_backend(backend)
    pred = predicate or is_quantizable
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    if not batched:
        if mesh is not None:
            raise ValueError("mesh= requires batched=True (the serial "
                             "baseline is single-device by definition)")
        return _quantize_tree_serial(flat, treedef, pred, method, bits,
                                     group_size, scale_method, dequantize)
    return _quantize_tree_batched(flat, treedef, pred, method, bits,
                                  group_size, scale_method, dequantize,
                                  backend, mesh, mesh_axis)
