"""Data pipeline: deterministic synthetic streams + byte tokenizer."""
from repro.data.synthetic import synthetic_batches, markov_batches  # noqa: F401
from repro.data.tokenizer import ByteTokenizer  # noqa: F401
