"""Deterministic synthetic token pipelines.

* ``synthetic_batches`` — uniform random tokens (throughput/compile tests).
* ``markov_batches``    — an order-2 Markov stream with a low-entropy
  transition structure: a model that learns reduces loss well below
  log(vocab), so trainer tests can assert real learning.

Both are host-side generators yielding already-sharded-ready numpy batches;
in the multi-host setting each host generates only its addressable slice
(deterministic per (seed, step, host)).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


def synthetic_batches(batch: int, seq: int, vocab: int, seed: int = 0,
                      encdec_dim: Optional[int] = None,
                      enc_ratio: int = 4) -> Iterator[Dict[str, np.ndarray]]:
    step = 0
    while True:
        rng = np.random.default_rng(seed * 1_000_003 + step)
        tokens = rng.integers(0, vocab, size=(batch, seq), dtype=np.int32)
        out = {"tokens": tokens, "labels": tokens.copy()}
        if encdec_dim is not None:
            out["enc_frames"] = rng.normal(
                size=(batch, max(1, seq // enc_ratio), encdec_dim)
            ).astype(np.float32)
        yield out
        step += 1


def _markov_tables(vocab: int, seed: int, branch: int = 4):
    rng = np.random.default_rng(seed)
    nxt = rng.integers(0, vocab, size=(vocab, branch)).astype(np.int32)
    probs = rng.dirichlet(np.full(branch, 0.3), size=vocab).astype(np.float32)
    return nxt, probs


def markov_batches(batch: int, seq: int, vocab: int, seed: int = 0,
                   encdec_dim: Optional[int] = None,
                   enc_ratio: int = 4, start: int = 0
                   ) -> Iterator[Dict[str, np.ndarray]]:
    """``start`` offsets the batch counter: a held-out eval split is the
    same transition tables (same ``seed``) at a disjoint step window."""
    nxt, probs = _markov_tables(vocab, seed)
    step = start
    while True:
        rng = np.random.default_rng(seed * 7_919 + step + 1)
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, vocab, size=batch)
        for t in range(seq):
            cur = toks[:, t]
            choice = np.array([rng.choice(nxt.shape[1], p=probs[c])
                               for c in cur])
            toks[:, t + 1] = nxt[cur, choice]
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
        if encdec_dim is not None:
            out["enc_frames"] = rng.normal(
                size=(batch, max(1, seq // enc_ratio), encdec_dim)
            ).astype(np.float32)
        yield out
        step += 1
