"""Minimal byte-level tokenizer (vocab 256 + specials) for runnable
text examples without external assets."""
from __future__ import annotations

from typing import List

PAD, BOS, EOS = 256, 257, 258
VOCAB = 259


class ByteTokenizer:
    vocab_size = VOCAB
    pad_id, bos_id, eos_id = PAD, BOS, EOS

    def encode(self, text: str, add_bos: bool = True) -> List[int]:
        ids = list(text.encode("utf-8"))
        return ([BOS] if add_bos else []) + ids

    def decode(self, ids) -> str:
        b = bytes(i for i in ids if 0 <= i < 256)
        return b.decode("utf-8", errors="replace")
