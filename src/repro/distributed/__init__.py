"""Distribution: logical-axis sharding rules, mesh helpers, collectives."""
from repro.distributed.sharding import (  # noqa: F401
    AxisRules, param_sharding_rules, shard_act, set_axis_rules,
    make_param_shardings, logical_to_mesh)
