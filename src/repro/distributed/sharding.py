"""Logical-axis sharding: the single place where model dimensions meet mesh
axes.

Model code annotates activations with *logical* axis names
(``shard_act(x, ("batch", "seq", "embed"))``); parameters get specs from
name/shape rules (``make_param_shardings``). The mapping logical→mesh lives
in an ``AxisRules`` table so the same model lowers on a laptop (trivial mesh),
a 256-chip pod, or the 512-chip 2-pod production mesh.

Defaults implement the MaxText-standard regime for this scale:
* DP over ('pod', 'data')   — batch dim
* TP over 'model'           — heads / ff / vocab / experts
* FSDP (ZeRO-3) over 'data' — every parameter's non-TP dim
* SP over 'data'            — long-context KV/state sequence dim

Divisibility-aware: a dim is only assigned a mesh axis when the axis size
divides it (e.g. mixtral's 8 experts on a 16-way 'model' axis fall back to
FSDP-only and the expert ffn dim takes TP instead).
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import compat


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """logical axis name → tuple of candidate mesh axes (first that fits)."""
    rules: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
        ("batch",        (("pod", "data"), ("data",), None)),
        ("seq",          (None,)),
        ("seq_shard",    (("data",), None)),           # SP for long context
        ("embed",        (None,)),
        ("heads",        (("model",), None)),
        ("kv_heads",     (("model",), None)),
        ("seq_model",    (("model",), None)),
        ("head_dim",     (None,)),
        ("ff",           (("model",), None)),
        ("vocab",        (("model",), None)),
        ("experts",      (("model",), None)),
        ("expert_ff",    (("model",), None)),
        ("fsdp",         (("data",), None)),
        ("conv",         (None,)),
        ("state",        (None,)),
    )

    def lookup(self, name: str) -> Tuple:
        for k, v in self.rules:
            if k == name:
                return v
        return (None,)


_STATE = threading.local()


def set_axis_rules(rules: Optional[AxisRules]):
    _STATE.rules = rules


def _get_rules() -> AxisRules:
    return getattr(_STATE, "rules", None) or AxisRules()


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    """Usable (non-Manual) axis sizes; works for Mesh and AbstractMesh.

    Inside a shard_map, manual axes (e.g. 'pod' in the compressed-gradient
    step) must not appear in sharding constraints — the per-shard program
    only sees the remaining auto axes.
    """
    sizes = dict(mesh.shape)
    try:
        types = dict(zip(mesh.axis_names, mesh.axis_types))
        manual = {n for n, t in types.items()
                  if str(t).endswith("Manual")}
        for n in manual:
            sizes.pop(n, None)
    except Exception:
        pass
    # Old jax meshes carry no axis types; an enclosing compat.shard_map
    # records its manual axes in a thread-local instead.
    for n in compat.manual_axes_in_scope():
        sizes.pop(n, None)
    return sizes


def logical_to_mesh(logical: Sequence[Optional[str]], shape: Sequence[int],
                    mesh: Mesh, rules: Optional[AxisRules] = None) -> P:
    """Resolve logical axes to a PartitionSpec, honouring divisibility and
    never assigning one mesh axis twice."""
    rules = rules or _get_rules()
    sizes = _mesh_axis_sizes(mesh)
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        assigned = None
        if name is not None:
            for cand in rules.lookup(name):
                if cand is None:
                    break
                cand_t = cand if isinstance(cand, tuple) else (cand,)
                if any(c not in sizes for c in cand_t):
                    continue
                if any(c in used for c in cand_t):
                    continue
                total = int(np.prod([sizes[c] for c in cand_t]))
                if dim % total == 0:
                    assigned = cand_t if len(cand_t) > 1 else cand_t[0]
                    used.update(cand_t)
                    break
        out.append(assigned)
    return P(*out)


def shard_act(x: jax.Array, logical: Sequence[Optional[str]],
              mesh: Optional[Mesh] = None) -> jax.Array:
    """Annotate an activation with a sharding constraint if a mesh is active.

    Outside a mesh context (unit tests, single-device smoke runs) this is an
    identity — model code stays mesh-agnostic.
    """
    mesh = mesh or _current_mesh()
    if mesh is None or getattr(mesh, "empty", True) or mesh.size == 1:
        return x
    spec = logical_to_mesh(logical, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, spec)


def _current_mesh():
    return compat.current_mesh()


def shard_logits(logits: jax.Array) -> jax.Array:
    """LM-head logits: vocab-sharded over 'model' when divisible (the
    matmul-natural layout from the sharded embedding), otherwise
    sequence-sharded over 'model' — never replicate a (B, S, V) f32 tensor
    (a 200 GB/chip blow-up for 256k-vocab non-divisible models; found by
    the dry-run)."""
    mesh = _current_mesh()
    if mesh is None or getattr(mesh, "empty", True) or mesh.size == 1:
        return logits
    sizes = _mesh_axis_sizes(mesh)
    tp = sizes.get("model", 1)
    if logits.shape[-1] % tp == 0:
        return shard_act(logits, ("batch", None, "vocab"), mesh)
    if logits.ndim == 3 and logits.shape[1] % tp == 0:
        return shard_act(logits, ("batch", "seq_model", None), mesh)
    return shard_act(logits, ("batch", None, None), mesh)


# ---------------------------------------------------------------------------
# Parameter sharding rules (path/shape based)
# ---------------------------------------------------------------------------
# Conventions (repro.models):
#   dense kernels    {"<proj>": {"w": (in, out)}}         — leaf name "w"
#   expert banks     {"moe": {"wi": {"w": (E, in, out)}}} — "moe" in path
#   embeddings       {"embedding": (V, d)}; lm head {"lm_head": {"w": (d, V)}}
#   scanned stacks prepend one period dim ("periods" in path)
#
# Megatron-style placement: column-parallel projections put TP on the out
# dim, row-parallel on the in dim; everything else gets FSDP on its largest
# eligible dim. Divisibility fallbacks in logical_to_mesh handle the rest
# (e.g. mixtral's 8 experts on model=16 fall back to expert-ff TP).

_ROW_PARALLEL = {"wo", "wdown", "out_proj", "w_lora_b", "wv_cm"}
_TP = "heads_flat"   # resolves to 'model'

_PARAM_RULES = AxisRules(rules=AxisRules().rules + (
    ("heads_flat", (("model",), None)),
))


def param_sharding_rules(path: Tuple[str, ...], leaf: Any) -> Tuple:
    """Logical axes for a parameter leaf."""
    shape = getattr(leaf, "shape", ())
    rank = len(shape)
    name = path[-1] if path else ""
    parent = path[-2] if len(path) >= 2 else ""

    def pad(spec: Tuple) -> Tuple:
        """Prepend Nones for the stack dim(s) so spec matches rank."""
        if len(spec) < rank:
            return (None,) * (rank - len(spec)) + spec
        return spec

    if name == "embedding":
        return pad(("vocab", "fsdp"))
    if name == "conv_w":
        return pad((None, "ff"))
    if name in ("w", "w_q", "w_q4", "w_scale"):
        if parent == "w" or parent == "":
            return (None,) * rank
        if parent in ("w_lora_a", "w_lora_b") and \
                not os.environ.get("REPRO_LORA_TP"):
            # rwkv decay LoRA: ~0.26 M params/layer — replicating them and
            # duplicating the tiny matmul removes a (B,S,d) psum + the
            # surrounding reshard per layer (§Perf hillclimb: the
            # most-collective-bound cell). REPRO_LORA_TP=1 restores the
            # naive TP sharding for the before/after measurement.
            return pad(("fsdp", None))
        if "lm_head" in path:
            spec = ("fsdp", "vocab")
        elif "moe" in path:                    # expert bank (E, in, out)
            spec = ("experts", "fsdp", "expert_ff")
        else:
            key = parent
            if key == "wv" and "cm" in path:
                key = "wv_cm"                  # rwkv channel-mix down-proj
            spec = (_TP, "fsdp") if key in _ROW_PARALLEL else ("fsdp", _TP)
        if name in ("w_q", "w_q4"):            # quantized codes: (out, in)
            spec = spec[:-2] + (spec[-1], spec[-2])
        elif name == "w_scale":                # (out, 1)
            spec = spec[:-2] + (spec[-1], None)
        return pad(spec)
    # vectors / norm gains / lerp factors / u bonus: replicate
    return (None,) * rank


def make_param_shardings(mesh: Mesh, params_shape: Any,
                         rules: Optional[AxisRules] = None) -> Any:
    """NamedSharding pytree for a params pytree (of arrays or
    ShapeDtypeStructs)."""
    rules = rules or _PARAM_RULES

    def one(keypath, leaf):
        path = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in keypath)
        logical = param_sharding_rules(path, leaf)
        spec = logical_to_mesh(logical, leaf.shape, mesh, rules)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def quantized_tensor_shardings(mesh: Mesh, path: Tuple[str, ...], qt
                               ) -> Tuple[NamedSharding, NamedSharding]:
    """(codes, scale) NamedShardings for a pipeline ``QuantizedTensor``.

    ``path`` is the source kernel's tree path (…, parent, "w"); the codes and
    scales inherit the *serving-format* rules the same kernel would get as
    plain ``w_q``/``w_q4``/``w_scale`` arrays (transposed Megatron placement,
    divisibility fallbacks included). The pipeline's 2-D carriers collapse
    any stacked dims (experts, scan periods, conv taps) into the row dim, so
    rules written for the full stacked rank keep their trailing (row, col)
    entries — non-divisible collapsed dims fall back to replication inside
    ``logical_to_mesh``.
    """
    qname = "w_q4" if qt.packed else "w_q"
    logical_q = param_sharding_rules(path[:-1] + (qname,), qt.data)
    logical_s = param_sharding_rules(path[:-1] + ("w_scale",), qt.scale)
    logical_q = tuple(logical_q)[-qt.data.ndim:]
    logical_s = tuple(logical_s)[-qt.scale.ndim:]
    spec_q = logical_to_mesh(logical_q, qt.data.shape, mesh, _PARAM_RULES)
    spec_s = logical_to_mesh(logical_s, qt.scale.shape, mesh, _PARAM_RULES)
    return NamedSharding(mesh, spec_q), NamedSharding(mesh, spec_s)


def reshard_serving_tree(tree: Any, mesh: Mesh) -> Any:
    """Place every leaf of a serving weight tree (fp params or the quantized
    ``w_q``/``w_q4``/``w_scale`` qdict format) onto ``mesh``'s parameter
    shardings — the reshard-on-restore path: a tree checkpointed from an
    8-device mesh lands bit-exactly on a 1- or 2-device mesh because the
    checkpoint holds full logical arrays and ``device_put`` only re-splits
    them. Asynchronous (no host sync)."""
    shardings = make_param_shardings(mesh, tree)
    return jax.tree_util.tree_map(jax.device_put, tree, shardings)


# ---------------------------------------------------------------------------
# Cache sharding rules (serving)
# ---------------------------------------------------------------------------

_CACHE_LOGICAL: Dict[str, Tuple] = {
    "k":       ("batch", "seq_cache", "kv_heads", "head_dim"),
    "v":       ("batch", "seq_cache", "kv_heads", "head_dim"),
    "k_scale": ("batch", "seq_cache", "kv_heads"),
    "v_scale": ("batch", "seq_cache", "kv_heads"),
    "c_kv":    ("batch", "seq_cache", "mla_rank"),
    "k_rope":  ("batch", "seq_cache", None, None),
    "h":       ("batch", "d_inner", None),
    "conv":    ("batch", None, "d_inner"),
    "wkv":     ("batch", "heads", None, None),
    "x_tm":    ("batch", None),
    "x_cm":    ("batch", None),
    "enc_out": ("batch", None, None),
}

# Paged pools put K/V in (num_blocks, block_size, kv_heads, head_dim):
# the pool dim takes the DP axes (each device holds a slice of the block
# pool — the paged analogue of sequence parallelism; block tables index
# logically so the gather reshards transparently under GSPMD), while the
# tiny block_size dim is never split.
_PAGED_CACHE_LOGICAL: Dict[str, Tuple] = {
    "k":       ("kv_blocks", None, "kv_heads", "head_dim"),
    "v":       ("kv_blocks", None, "kv_heads", "head_dim"),
    "k_scale": ("kv_blocks", None, "kv_heads"),
    "v_scale": ("kv_blocks", None, "kv_heads"),
}

_CACHE_RULES = AxisRules(rules=(
    # long-context SP: the cache sequence dim takes whatever DP axes the
    # (possibly tiny) batch left unused — 500k decode shards its KV over them
    ("seq_cache", (("pod", "data"), ("data",), None)),
    ("kv_blocks", (("pod", "data"), ("data",), None)),
    ("mla_rank",  (("model",), None)),
    ("d_inner",   (("model",), None)),
    ("head_dim",  (("model",), None)),
) + AxisRules().rules)


def make_cache_shardings(mesh: Mesh, cache_shape: Any,
                         paged: bool = False) -> Any:
    table = _PAGED_CACHE_LOGICAL if paged else _CACHE_LOGICAL

    def one(keypath, leaf):
        path = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in keypath)
        name = path[-1] if path else ""
        logical = table.get(name)
        if logical is None and paged:
            logical = _CACHE_LOGICAL.get(name)
        if logical is None:
            logical = (None,) * len(leaf.shape)
        if len(logical) != len(leaf.shape):
            stack = len(leaf.shape) - len(logical)
            logical = (None,) * stack + tuple(logical)
        spec = logical_to_mesh(logical, leaf.shape, mesh, _CACHE_RULES)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, cache_shape)
