"""Version-portable wrappers around the jax.sharding surface.

The distributed layer targets two generations of the jax API:

* **new** (jax >= ~0.6): ``jax.shard_map`` with ``axis_names``/``check_vma``,
  ``jax.sharding.AxisType`` + ``axis_types=`` on ``jax.make_mesh``,
  ``jax.sharding.set_mesh`` / ``get_abstract_mesh``.
* **old** (jax 0.4.x, what this container ships): ``shard_map`` lives in
  ``jax.experimental.shard_map`` with ``auto=``/``check_rep=``, meshes have
  no axis types, and the ambient mesh is the ``Mesh`` context manager backed
  by ``thread_resources``.

Everything in the repo that builds meshes or shard_maps goes through this
module so the same code (and the same tests) runs on either generation.
All shims are feature-detected, never version-parsed.
"""
from __future__ import annotations

import threading
from typing import Callable, FrozenSet, Iterable, Optional, Sequence

import jax

_NEW_SHARD_MAP = hasattr(jax, "shard_map")
_NEW_AXIS_TYPES = hasattr(jax.sharding, "AxisType")
_NEW_SET_MESH = hasattr(jax.sharding, "set_mesh")


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None):
    """``jax.make_mesh`` with explicitly-Auto axis types where supported.

    Auto axis types are the GSPMD default this codebase assumes everywhere;
    on old jax the concept does not exist and every axis is implicitly auto
    outside a shard_map.
    """
    kwargs = {} if devices is None else {"devices": devices}
    if _NEW_AXIS_TYPES:
        try:
            return jax.make_mesh(
                tuple(axis_shapes), tuple(axis_names),
                axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
                **kwargs)
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------
# Old jax has no Manual axis types on the mesh, so code inside a partial-auto
# shard_map body cannot ask the mesh which axes are manual (sharding.
# _mesh_axis_sizes needs to know: manual axes must not appear in sharding
# constraints). We track the manual set in a thread-local that the wrapped
# body pushes during tracing.

_SCOPE = threading.local()


def manual_axes_in_scope() -> FrozenSet[str]:
    """Mesh axes manually mapped by an enclosing ``shard_map`` (old jax only;
    new jax exposes the same information via ``mesh.axis_types``)."""
    return getattr(_SCOPE, "axes", frozenset())


def shard_map(f: Callable, mesh, in_specs, out_specs,
              manual_axes: Optional[Iterable[str]] = None,
              check: bool = False) -> Callable:
    """Portable shard_map.

    ``manual_axes`` names the mesh axes the body is manually mapped over
    (None → all of them); the remaining axes stay auto (GSPMD partitions the
    per-shard program as usual). ``check`` maps to ``check_vma``/``check_rep``.
    """
    all_axes = frozenset(mesh.axis_names)
    manual = frozenset(manual_axes) if manual_axes is not None else all_axes
    unknown = manual - all_axes
    if unknown:
        raise ValueError(f"manual axes {sorted(unknown)} not in mesh axes "
                         f"{sorted(all_axes)}")
    if _NEW_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=manual,
                             check_vma=check)

    from jax.experimental.shard_map import shard_map as _shard_map

    def body(*args):
        prev = manual_axes_in_scope()
        _SCOPE.axes = prev | manual
        try:
            return f(*args)
        finally:
            _SCOPE.axes = prev

    return _shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check,
                      auto=all_axes - manual)


# ---------------------------------------------------------------------------
# Ambient mesh
# ---------------------------------------------------------------------------

def activate_mesh(mesh):
    """Install ``mesh`` as the ambient mesh for the rest of the process.

    Launcher-style (dryrun/train/quantize CLIs call this once after building
    the production mesh): on new jax it is ``jax.sharding.set_mesh``; on old
    jax the ``Mesh`` context manager is entered and intentionally never
    exited — the process owns exactly one mesh for its lifetime.
    """
    if _NEW_SET_MESH:
        jax.sharding.set_mesh(mesh)
    else:
        mesh.__enter__()
    return mesh


def current_mesh():
    """The ambient mesh, or None. Works inside and outside jit tracing."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and not mesh.empty:
            return mesh
    except AttributeError:
        pass
    except Exception:
        return None
    try:
        from jax._src import mesh as _mesh_lib
        mesh = _mesh_lib.thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty:
            return mesh
    except Exception:
        pass
    return None
