"""CI bench-regression gate.

Re-runs the serving-scheduler benchmark at smoke scale, plus
``bench_reload``'s stage-latency table (fixed-size workloads), and compares
against the committed baselines in ``benchmarks/BENCH_*.json``. Only
scale-free metrics (throughput ratios, dip percentages, swap-lag steps,
the chunked/monolithic p99 step-time ratio) and fixed-size latencies are
compared, and tolerances are deliberately generous — the gate exists to
catch >2x regressions (a scheduler that stopped batching, a stall
serializing the swap path, chunked prefill that stopped bounding the
admission spike, a paged KV cache that stopped reusing prefixes), not
wall-clock noise across runners. Some hard floors are absolute: chunked
greedy tokens must stay bit-identical to the monolithic path (contiguous
and paged admission alike) and paged tokens to the contiguous backend;
the int8-KV config's teacher-forced greedy agreement vs the fp paged
oracle must stay at or above its 0.98 tolerance budget and its
bytes-per-position ratio at or under 0.6x fp;
every row of the per-architecture chunked-prefill agreement ladder
(sliding-window / MLA / MoE / mamba / rwkv, plus the composed mixtral
stack) must stay at or above its composed ``AGREEMENT_BUDGETS`` floor,
fresh and committed alike — the machine-checked evidence that the
chunked-prefill architecture gates stay lifted (see
``docs/equivalence.md``);
self-speculative tokens must stay bit-identical to w8-only decode at
every draft bit-width measured;
the *committed baseline's* chunked/monolithic p99 ratios must stay at or
under 0.5x, its
shared-prefix paged/contiguous throughput ratio at or above 1.3x, and
its speculative/w8-only throughput ratio at or above 1.0x (the
acceptance bars those PRs landed — re-committing a degraded baseline
fails the gate; the fresh runs get the usual generous tolerance against
it). Fresh JSONs are written to ``--out-dir`` and uploaded as CI
artifacts by the ``bench-gate`` job.

Usage: PYTHONPATH=src python scripts/check_bench.py [--out-dir DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, os.path.join(REPO, "benchmarks"))

FAILURES = []


def check(name: str, ok: bool, detail: str) -> None:
    print(f"[bench-gate] {'PASS' if ok else 'FAIL'} {name}: {detail}")
    if not ok:
        FAILURES.append(f"{name}: {detail}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir",
                    default=os.path.join(REPO, "benchmarks"))
    ap.add_argument("--out-dir", default="bench-fresh")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    import bench_reload
    import bench_serving

    fresh_serving = bench_serving.run(
        smoke=True,
        out_path=os.path.join(args.out_dir, "BENCH_serving.json"))
    fresh_reload = {"stage_latency": bench_reload.bench_stage_latency()}
    with open(os.path.join(args.out_dir, "BENCH_reload.json"), "w") as f:
        json.dump(fresh_reload, f, indent=1)

    with open(os.path.join(args.baseline_dir, "BENCH_serving.json")) as f:
        base_serving = json.load(f)
    with open(os.path.join(args.baseline_dir, "BENCH_reload.json")) as f:
        base_reload = json.load(f)

    # --- serving: continuous batching must still beat static rounds ------
    # smoke-scale wall clock is noisy (tiny steps, admission dispatch
    # overhead), so the floor is structural: continuous must stay ahead of
    # round, capped at half the committed full-scale ratio
    ratio, base_ratio = (fresh_serving["throughput"]["ratio"],
                         base_serving["throughput"]["ratio"])
    floor = min(base_ratio / 2, 1.05)
    check("serving.throughput.ratio", ratio >= floor,
          f"continuous/round {ratio:.2f}x (baseline {base_ratio:.2f}x, "
          f"floor {floor:.2f}x)")

    # --- serving: the reload dip advantage must survive ------------------
    fr, fc = fresh_serving["reload"]["round"], \
        fresh_serving["reload"]["continuous"]
    bc = base_serving["reload"]["continuous"]
    check("serving.reload.dip-smaller-than-round",
          fc["dip_pct"] < fr["dip_pct"],
          f"continuous {fc['dip_pct']:.0f}% vs round {fr['dip_pct']:.0f}%")
    dip_cap = max(2.0 * bc["dip_pct"], 25.0)
    check("serving.reload.dip", fc["dip_pct"] <= dip_cap,
          f"continuous dip {fc['dip_pct']:.0f}% (cap {dip_cap:.0f}%)")
    lag_cap = max(2 * bc["swap_lag_steps"], 6)
    check("serving.reload.swap-lag", fc["swap_lag_steps"] <= lag_cap,
          f"{fc['swap_lag_steps']} steps (cap {lag_cap})")

    # --- serving: chunked prefill must keep bounding the admission spike -
    ft, bt = fresh_serving["prefill_tail"], base_serving["prefill_tail"]
    check("serving.prefill-tail.tokens-identical",
          ft["tokens_identical"] and ft["admission_clocks_identical"],
          "chunked greedy tokens/admission clocks vs monolithic")
    # the committed baseline must keep the acceptance bar (<= 0.5x), so a
    # degraded baseline can't be re-committed to relax the gate below...
    ratio, base_ratio = ft["p99_ratio"], bt["p99_ratio"]
    check("serving.prefill-tail.baseline-acceptance", base_ratio <= 0.5,
          f"committed chunked/monolithic p99 ratio {base_ratio:.2f}x "
          "(bar 0.50x)")
    # ...while the fresh run is held to >2x-regression-vs-baseline, plus an
    # absolute ceiling where chunking structurally stopped bounding spikes
    cap = min(2.0 * base_ratio, 0.95)
    check("serving.prefill-tail.p99-ratio", ratio <= cap,
          f"chunked/monolithic p99 step-time {ratio:.2f}x "
          f"(baseline {base_ratio:.2f}x, cap {cap:.2f}x)")

    # --- serving: paged KV must keep paying for itself on shared prefixes
    fs, bs_ = fresh_serving["shared_prefix"], base_serving["shared_prefix"]
    check("serving.shared-prefix.tokens-identical", fs["tokens_identical"],
          "paged greedy tokens vs contiguous backend")
    check("serving.shared-prefix.hit-rate",
          fs["paged"]["prefix_hit_rate"] > 0,
          f"prefix hit rate {fs['paged']['prefix_hit_rate']:.2f}")
    # the committed baseline must keep the acceptance bar (>= 1.3x) the
    # paged-KV PR landed — re-committing a degraded baseline fails the
    # gate; the fresh run is held to the usual structural floor
    ratio, base_ratio = fs["ratio"], bs_["ratio"]
    check("serving.shared-prefix.baseline-acceptance", base_ratio >= 1.3,
          f"committed paged/contiguous ratio {base_ratio:.2f}x (bar 1.30x)")
    floor = min(base_ratio / 2, 1.05)
    check("serving.shared-prefix.ratio", ratio >= floor,
          f"paged/contiguous {ratio:.2f}x (baseline {base_ratio:.2f}x, "
          f"floor {floor:.2f}x)")

    # --- serving: paged chunked admission must keep bounding the spike ---
    fp, bp = fresh_serving["paged_chunked"], base_serving["paged_chunked"]
    check("serving.paged-chunked.tokens-identical", fp["tokens_identical"],
          "paged chunked greedy tokens vs monolithic paged admission")
    check("serving.paged-chunked.hit-rate",
          fp["chunked"]["prefix_hit_rate"] > 0,
          f"prefix hit rate {fp['chunked']['prefix_hit_rate']:.2f}")
    # same bar structure as prefill-tail: the committed baseline must keep
    # the chunked-contiguous acceptance bar (<= 0.5x), the fresh run gets
    # >2x-vs-baseline tolerance under an absolute structural ceiling
    ratio, base_ratio = fp["p99_ratio"], bp["p99_ratio"]
    check("serving.paged-chunked.baseline-acceptance", base_ratio <= 0.5,
          f"committed chunked/monolithic p99 ratio {base_ratio:.2f}x "
          "(bar 0.50x)")
    cap = min(2.0 * base_ratio, 0.95)
    check("serving.paged-chunked.p99-ratio", ratio <= cap,
          f"chunked/monolithic p99 step-time {ratio:.2f}x "
          f"(baseline {base_ratio:.2f}x, cap {cap:.2f}x)")

    # --- serving: the int8 KV pool must keep its bytes win AND its
    # greedy-agreement budget (the tolerance-equivalence harness's first
    # enforced contract: quantized-KV tokens are not bit-identical, so the
    # hard floor is teacher-forced agreement vs the fp paged oracle) ------
    fk, bk = fresh_serving["kv_bytes"], base_serving["kv_bytes"]
    check("serving.kv-bytes.baseline-acceptance",
          bk["bytes_ratio"] <= 0.6,
          f"committed int8/fp bytes-per-position ratio "
          f"{bk['bytes_ratio']:.2f}x (bar 0.60x)")
    check("serving.kv-bytes.bytes-ratio", fk["bytes_ratio"] <= 0.6,
          f"int8/fp bytes-per-position {fk['bytes_ratio']:.2f}x "
          "(cap 0.60x)")
    # agreement is a hard floor on BOTH the committed baseline and the
    # fresh run: 0.98 is the per-config budget quantized KV serves under
    check("serving.kv-bytes.baseline-agreement", bk["agreement"] >= 0.98,
          f"committed greedy agreement {bk['agreement']:.4f} (floor 0.98)")
    check("serving.kv-bytes.agreement", fk["agreement"] >= 0.98,
          f"int8-KV greedy agreement {fk['agreement']:.4f} over "
          f"{fk['agreement_compared']} tokens (floor 0.98)")
    # throughput: int8 dequant must stay roughly free — the committed
    # baseline keeps a 0.5x bar, the fresh run the usual structural floor
    ratio, base_ratio = fk["throughput_ratio"], bk["throughput_ratio"]
    check("serving.kv-bytes.baseline-throughput", base_ratio >= 0.5,
          f"committed int8/fp throughput {base_ratio:.2f}x (bar 0.50x)")
    floor = min(base_ratio / 2, 0.4)
    check("serving.kv-bytes.throughput-ratio", ratio >= floor,
          f"int8/fp throughput {ratio:.2f}x (baseline {base_ratio:.2f}x, "
          f"floor {floor:.2f}x)")

    # --- serving: every ungated architecture must keep its chunked-
    # prefill agreement budget (the evidence the per-arch chunked-prefill
    # gates stayed lifted: each ladder row runs prefill_chunk > 0 on the
    # continuous scheduler and owes its composed AGREEMENT_BUDGETS floor,
    # fresh and committed alike — budgets are deterministic-greedy floors,
    # not wall-clock metrics, so no regression tolerance applies) --------
    fa, ba = (fresh_serving["chunked_archs"]["rows"],
              base_serving["chunked_archs"]["rows"])
    for label, brow in ba.items():
        budget = brow["budget"]
        check(f"serving.chunked-archs.{label}.baseline-agreement",
              brow["agreement"] >= budget,
              f"committed agreement {brow['agreement']:.4f} over "
              f"{brow['compared']} tokens (floor {budget:.3f}, "
              f"{brow['arch']})")
        frow = fa.get(label)
        check(f"serving.chunked-archs.{label}.agreement",
              frow is not None and frow["agreement"] >= budget,
              "ladder row missing from fresh run" if frow is None else
              f"fresh agreement {frow['agreement']:.4f} over "
              f"{frow['compared']} tokens (floor {budget:.3f})")

    # --- serving: self-speculative decode must stay bit-identical and
    # keep paying for itself ----------------------------------------------
    fsp, bsp = fresh_serving["speculative"], base_serving["speculative"]
    # token identity is the tentpole contract — a hard floor on every
    # draft bit-width measured, fresh and committed alike
    check("serving.speculative.tokens-identical", fsp["tokens_identical"],
          "draft-assisted tokens == w8-only tokens (all bit-widths)")
    check("serving.speculative.baseline-tokens-identical",
          bsp["tokens_identical"],
          "committed baseline tokens_identical")
    # the committed baseline must show speculation actually paying:
    # headline throughput >= 1.0x w8-only (the PR's acceptance bar);
    # the fresh run gets the usual generous structural tolerance
    ratio, base_ratio = fsp["throughput_ratio"], bsp["throughput_ratio"]
    check("serving.speculative.baseline-acceptance", base_ratio >= 1.0,
          f"committed speculative/w8 throughput {base_ratio:.2f}x "
          "(bar 1.00x)")
    floor = min(base_ratio / 2, 0.7)
    check("serving.speculative.throughput-ratio", ratio >= floor,
          f"speculative/w8 throughput {ratio:.2f}x "
          f"(baseline {base_ratio:.2f}x, floor {floor:.2f}x)")
    # acceptance rate is scale-free (a property of the draft/verifier
    # pair on the fixed workload); hold fresh runs near the baseline
    acc, bacc = (fsp["speculative"]["acceptance_rate"],
                 bsp["speculative"]["acceptance_rate"])
    check("serving.speculative.acceptance-rate", acc >= bacc / 2,
          f"draft acceptance {acc:.2f} (baseline {bacc:.2f}, "
          f"floor {bacc / 2:.2f})")
    # steps-per-token is the mechanism: speculation must keep taking
    # fewer engine steps than verifier-only decode
    check("serving.speculative.steps-ratio", fsp["steps_ratio"] < 1.0,
          f"speculative/w8 engine steps {fsp['steps_ratio']:.2f}x "
          "(must be < 1.0x)")

    # --- reload: staging/swap latency on the fixed-size workloads --------
    for wl in ("toy_cnn", "reduced_lm"):
        fm, bm = fresh_reload["stage_latency"][wl], \
            base_reload["stage_latency"][wl]
        stage_cap = 2.0 * bm["stage_fp_quantize_ms"] + 250.0
        check(f"reload.stage-fp.{wl}",
              fm["stage_fp_quantize_ms"] <= stage_cap,
              f"{fm['stage_fp_quantize_ms']:.0f} ms "
              f"(cap {stage_cap:.0f} ms)")
        swap_cap = max(2.0 * bm["swap_ms"], 5.0)
        check(f"reload.swap.{wl}", fm["swap_ms"] <= swap_cap,
              f"{fm['swap_ms']:.2f} ms (cap {swap_cap:.2f} ms)")

    if FAILURES:
        print(f"[bench-gate] {len(FAILURES)} check(s) failed:")
        for msg in FAILURES:
            print(f"[bench-gate]   {msg}")
        sys.exit(1)
    print("[bench-gate] all checks passed")


if __name__ == "__main__":
    main()
