"""Regenerate EXPERIMENTS.md tables from artifacts + bench logs.

Usage: PYTHONPATH=src python scripts_build_experiments.py
"""
import glob
import json
import os
import re

ROOT = os.path.dirname(os.path.abspath(__file__))
ART = os.path.join(ROOT, "artifacts", "dryrun")

ARCHS = ["minitron-4b", "minicpm3-4b", "gemma-7b", "granite-3-8b",
         "seamless-m4t-medium", "chameleon-34b", "moonshot-v1-16b-a3b",
         "mixtral-8x7b", "rwkv6-1.6b", "jamba-1.5-large-398b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load():
    cells = {}
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        d = json.load(open(p))
        # filename is authoritative: <arch>__<shape>__<mesh>[__<tag>].json
        parts = os.path.basename(p)[:-5].split("__")
        key = (parts[0], parts[1], parts[2])
        tag = parts[3] if len(parts) > 3 else (d.get("tag") or "prod")
        cells.setdefault(key, {})[tag or "prod"] = d
    return cells


def fmt_ms(s):
    return f"{s*1e3:.2f}" if s is not None else "—"


def dryrun_table(cells):
    rows = ["| arch | shape | mesh | status | compile s | HBM/chip GB | "
            "fits 16 GB | collective MB/step |",
            "|---|---|---|---|---|---|---|---|"]
    for a in ARCHS:
        for s in SHAPES:
            for m in ("pod", "multipod"):
                d = cells.get((a, s, m), {}).get("prod")
                if d is None:
                    rows.append(f"| {a} | {s} | {m} | MISSING | | | | |")
                elif d["status"] == "skip":
                    rows.append(f"| {a} | {s} | {m} | skip (full attention "
                                f"@512k) | | | | |")
                elif d["status"] != "ok":
                    rows.append(f"| {a} | {s} | {m} | ERROR | | | | |")
                else:
                    coll = d.get("collectives", {}).get("total", 0) / 1e6
                    rows.append(
                        f"| {a} | {s} | {m} | ok | {d['compile_s']:.0f} | "
                        f"{d.get('hbm_per_chip_gb', -1):.2f} | "
                        f"{'✓' if d.get('fits_16gb') else '✗'} | "
                        f"{coll:.0f} |")
    return "\n".join(rows)


def roofline_table(cells):
    rows = ["| arch | shape | compute ms | memory ms (XLA ub) | "
            "mem floor ms | collective ms | dominant | roofline frac | "
            "useful/HLO flops |",
            "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCHS:
        for s in SHAPES:
            slot = cells.get((a, s, "pod"), {})
            prod, cost = slot.get("prod"), slot.get("cost")
            if prod is None and cost is None:
                continue
            d = cost or prod
            if d["status"] == "skip":
                rows.append(f"| {a} | {s} | skip | | | | | | |")
                continue
            if d["status"] != "ok":
                rows.append(f"| {a} | {s} | error | | | | | | |")
                continue
            rc = (cost or {}).get("roofline", {})
            rp = (prod or {}).get("roofline", {})
            comp = rc.get("compute_s", rp.get("compute_s", 0))
            mem = rc.get("memory_s", rp.get("memory_s", 0))
            floor = rp.get("memory_floor_s", rc.get("memory_floor_s", 0))
            coll = rp.get("collective_s", 0)
            n = d.get("n_chips", 256)
            useful = d.get("model_flops", 0) / n / 197e12
            bound = max(comp, mem, coll, 1e-30)
            dom = max((("compute", comp), ("memory(ub)", mem),
                       ("collective", coll)), key=lambda kv: kv[1])[0]
            ur = rc.get("model_flops_ratio")
            rows.append(
                f"| {a} | {s} | {fmt_ms(comp)} | {fmt_ms(mem)} | "
                f"{fmt_ms(floor)} | {fmt_ms(coll)} | {dom} | "
                f"{useful/bound:.3f} | "
                f"{'—' if ur is None else f'{ur:.3f}'} |")
    return "\n".join(rows)


def perf_variants(cells):
    out = []
    for (a, s, m), slots in sorted(cells.items()):
        extra = [t for t in slots if t not in ("prod", "cost")
                 and not t.startswith("cost")]
        for t in extra:
            d = slots[t]
            if d.get("status") != "ok":
                continue
            cd = slots.get(f"cost-{t}")
            r = (cd or d).get("roofline", {})
            rp = d.get("roofline", {})
            out.append(
                f"* `{a} {s} {m}` **[{t}]**: "
                f"compute {fmt_ms(r.get('compute_s', rp.get('compute_s')))} ms, "
                f"memory(ub) {fmt_ms(r.get('memory_s', rp.get('memory_s')))} ms, "
                f"floor {fmt_ms(rp.get('memory_floor_s'))} ms, "
                f"collective {fmt_ms(rp.get('collective_s'))} ms, "
                f"HBM {d.get('hbm_per_chip_gb', -1):.2f} GB "
                f"(fits: {d.get('fits_16gb')})")
    return "\n".join(out)


def main():
    cells = load()
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    text = re.sub(r"<!-- DRYRUN_TABLE -->.*?(?=\n## |$)",
                  "<!-- DRYRUN_TABLE -->\n\n" + dryrun_table(cells) + "\n\n",
                  text, flags=re.S)
    text = re.sub(r"<!-- ROOFLINE_TABLE -->.*?(?=\n## |$)",
                  "<!-- ROOFLINE_TABLE -->\n\n" + roofline_table(cells)
                  + "\n\n### Measured hillclimb variants\n\n"
                  + perf_variants(cells) + "\n\n",
                  text, flags=re.S)
    open(path, "w").write(text)
    print("EXPERIMENTS.md tables regenerated "
          f"({len(cells)} cells, {sum(len(v) for v in cells.values())} "
          "artifacts)")


if __name__ == "__main__":
    main()
